"""Shared benchmark harness: YCSB-style workloads over the Sherman index.

Scaled to the CPU container (smaller keyspace / op counts than the paper's
1B-key, 8-server cluster) — the netsim plane (repro.core.netsim) prices the
measured structural metrics with the paper's hardware constants, so the
*ratios* (Sherman vs FG+, ablation ladder, skew collapse) are the
reproduction targets; EXPERIMENTS.md compares them against the paper's.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import ShermanIndex, TreeConfig
from repro.core.netsim import Features, NetConfig

DEFAULT_CFG = TreeConfig(n_ms=4, nodes_per_ms=4096, fanout=16,
                         n_locks_per_ms=4096, max_height=7, n_cs=8)
KEYSPACE = 1 << 20
BULK = 60_000


_ZETA_CACHE: dict = {}


def _zeta(n: int, theta: float) -> float:
    key = (n, theta)
    if key not in _ZETA_CACHE:
        # zeta(n, theta) with an integral tail approximation (fast + exact
        # enough for the YCSB generator)
        head = np.sum(1.0 / np.arange(1, 10_001) ** theta) \
            if n > 10_000 else np.sum(1.0 / np.arange(1, n + 1) ** theta)
        tail = ((n ** (1 - theta) - 10_000 ** (1 - theta)) / (1 - theta)
                if n > 10_000 else 0.0)
        _ZETA_CACHE[key] = float(head + tail)
    return _ZETA_CACHE[key]


def zipf_keys(rng, n, keyspace, theta: float) -> np.ndarray:
    """YCSB ZipfianGenerator (Gray et al.), vectorized.

    Rank 0 receives ~1/zeta of all accesses (≈6-7% at theta=0.99 over 2^20
    keys) — the contention the paper's skewed workloads are about."""
    if theta <= 0.0:
        return rng.integers(0, keyspace, size=n).astype(np.int64)
    zetan = _zeta(keyspace, theta)
    zeta2 = _zeta(2, theta)
    alpha = 1.0 / (1.0 - theta)
    eta = (1 - (2.0 / keyspace) ** (1 - theta)) / (1 - zeta2 / zetan)
    u = rng.random(n)
    uz = u * zetan
    ranks = np.where(
        uz < 1.0, 0,
        np.where(uz < 1.0 + 0.5 ** theta, 1,
                 (keyspace * (eta * u - eta + 1) ** alpha).astype(np.int64)))
    ranks = np.clip(ranks, 0, keyspace - 1).astype(np.int64)
    # scatter hot ranks across the keyspace deterministically
    return (ranks * 2_654_435_761) % keyspace


@dataclasses.dataclass
class RunResult:
    mops: float
    p50_us: float
    p90_us: float
    p99_us: float
    counters: dict


def build_index(features: Features, cfg: TreeConfig = DEFAULT_CFG,
                bulk: int = BULK, cache_bytes: int = 64 << 20,
                seed: int = 0) -> ShermanIndex:
    rng = np.random.default_rng(seed)
    keys = rng.choice(KEYSPACE, size=bulk, replace=False)
    vals = rng.integers(0, 1 << 30, size=bulk)
    return ShermanIndex.build(cfg, keys, vals, features=features,
                              cache_bytes=cache_bytes)


def run_mix(idx: ShermanIndex, *, read_frac: float, skew: float,
            n_ops: int = 8_192, batch: int = 1_024, range_frac: float = 0.0,
            range_size: int = 0, seed: int = 1) -> RunResult:
    """Run a read/write/range mix and derive netsim performance."""
    rng = np.random.default_rng(seed)
    for s in range(0, n_ops, batch):
        b = min(batch, n_ops - s)
        keys = zipf_keys(rng, b, KEYSPACE, skew).astype(np.int32)
        r = rng.random(b)
        n_read = int(read_frac * b)
        n_range = int(range_frac * b)
        if n_range:
            idx.range(keys[:n_range], count=range_size,
                      max_leaves=max(4, range_size))
        if n_read:
            idx.lookup(keys[n_range:n_range + n_read])
        rest = keys[n_range + n_read:]
        if rest.size:
            idx.insert(rest, rng.integers(0, 1 << 30, rest.size
                                          ).astype(np.int32))
    lat = []
    if idx.latencies_write:
        lat.append(np.concatenate(idx.latencies_write))
    if idx.latencies_read:
        lat.append(np.concatenate(idx.latencies_read))
    lat = np.concatenate(lat) if lat else np.zeros(1)
    return RunResult(
        mops=idx.throughput_mops(),
        p50_us=float(np.percentile(lat, 50)) * 1e6,
        p90_us=float(np.percentile(lat, 90)) * 1e6,
        p99_us=float(np.percentile(lat, 99)) * 1e6,
        counters=dict(idx.counters))


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"
