"""Shared benchmark harness — now a thin shim over :mod:`repro.workloads`.

The workload engine (specs, key generators, driver, ``RunResult``) lives in
``src/repro/workloads``; this module keeps the historical benchmark entry
points (``build_index``, ``run_mix``, ``zipf_keys``) as aliases so older
scripts keep working.  New code should import ``repro.workloads`` directly.

Scaled to the CPU container (smaller keyspace / op counts than the paper's
1B-key, 8-server cluster) — the netsim plane (repro.core.netsim) prices the
measured structural metrics with the paper's hardware constants, so the
*ratios* (Sherman vs FG+, ablation ladder, skew collapse) are the
reproduction targets.
"""
from __future__ import annotations

from repro.core import TreeConfig
from repro.core.netsim import Features
from repro.workloads import (DEFAULT_CFG, KEYSPACE, RunResult, WorkloadSpec,
                             live_records, run_workload, zipf_keys)
from repro.workloads import build_index as _build_index

__all__ = ["DEFAULT_CFG", "KEYSPACE", "BULK", "RunResult", "zipf_keys",
           "build_index", "run_mix", "csv_row"]

BULK = 60_000


def build_index(features: Features, cfg: TreeConfig = DEFAULT_CFG,
                bulk: int = BULK, cache_bytes: int = 64 << 20,
                seed: int = 0):
    return _build_index(features, cfg, records=bulk,
                        cache_bytes=cache_bytes, seed=seed)


def run_mix(idx, *, read_frac: float, skew: float, n_ops: int = 8_192,
            batch: int = 1_024, range_frac: float = 0.0,
            range_size: int = 0, seed: int = 1) -> RunResult:
    """Historical entry point: an ad-hoc read/write/range mix.

    The distribution draws over the records actually live in ``idx``
    (however it was loaded), so reads hit and updates contend."""
    spec = WorkloadSpec(
        name="adhoc", read=read_frac, scan=range_frac,
        update=max(0.0, 1.0 - read_frac - range_frac), theta=skew,
        ops=n_ops, batch=batch, scan_len=range_size or 10,
        load_records=max(1, live_records(idx)))
    return run_workload(idx, spec, seed=seed)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"
