"""Legacy benchmark entry points — a thin shim over :mod:`repro.workloads`.

The workload engine (specs, key generators, driver, ``RunResult``) lives
in ``src/repro/workloads``; this module keeps exactly the documented
historical aliases (``build_index``, ``run_mix``, ``zipf_keys``) so older
scripts keep working.  Everything else that used to live here (figure
CSV helpers, private workload mixes, tree configs) has moved to
``benchmarks/paper_figs.py`` and ``repro.workloads`` — import from
there.
"""
from __future__ import annotations

from repro.core import TreeConfig
from repro.core.netsim import Features
from repro.workloads import (DEFAULT_CFG, RunResult, WorkloadSpec,
                             live_records, run_workload, zipf_keys)
from repro.workloads import build_index as _build_index

__all__ = ["build_index", "run_mix", "zipf_keys"]


def build_index(features: Features, cfg: TreeConfig = DEFAULT_CFG,
                bulk: int = 60_000, cache_bytes: int = 64 << 20,
                seed: int = 0):
    return _build_index(features, cfg, records=bulk,
                        cache_bytes=cache_bytes, seed=seed)


def run_mix(idx, *, read_frac: float, skew: float, n_ops: int = 8_192,
            batch: int = 1_024, range_frac: float = 0.0,
            range_size: int = 0, seed: int = 1) -> RunResult:
    """Historical entry point: an ad-hoc read/write/range mix.

    The distribution draws over the records actually live in ``idx``
    (however it was loaded), so reads hit and updates contend."""
    spec = WorkloadSpec(
        name="adhoc", read=read_frac, scan=range_frac,
        update=max(0.0, 1.0 - read_frac - range_frac), theta=skew,
        ops=n_ops, batch=batch, scan_len=range_size or 10,
        load_records=max(1, live_records(idx)))
    return run_workload(idx, spec, seed=seed)
