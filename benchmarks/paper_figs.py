"""One function per paper table/figure (Sherman, SIGMOD'22).

Each returns a list of CSV rows "name,us_per_call,derived" and prints a
small human table.  All workload mixes come from the unified engine in
:mod:`repro.workloads` (Table 3 presets: write-only, write-intensive,
read-intensive, range-only, range-write) — this module holds no private
workload logic, only figure orchestration.
"""
from __future__ import annotations

import numpy as np

from repro.core.netsim import ABLATION_LADDER, FG_PLUS, SHERMAN, NetConfig
from repro.workloads import (DEFAULT_CFG, build_index, get_preset,
                             run_workload)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"


def _run(features, skew, wl="write-intensive", n_ops=6_144, *, cfg=None,
         records=60_000, cache_bytes=64 << 20, **spec_kw):
    spec = get_preset(wl, theta=skew, ops=n_ops, load_records=records,
                      **spec_kw)
    idx = build_index(features, cfg or DEFAULT_CFG, records=records,
                      cache_bytes=cache_bytes)
    return idx, run_workload(idx, spec)


def table1_one_sided(n_ops=6_144):
    """§3.1 Table 1: the one-sided approach (FG+) across workloads."""
    rows = []
    print("\n== Table 1: one-sided approach (FG+) ==")
    print(f"{'workload':18s} {'dist':8s} {'Mops':>8s} {'p50us':>8s} "
          f"{'p99us':>10s}")
    for wl in ("read-intensive", "write-intensive"):
        for dist, skew in (("uniform", 0.0), ("skew", 0.99)):
            _, r = _run(FG_PLUS, skew, wl, n_ops)
            print(f"{wl:18s} {dist:8s} {r.mops:8.2f} {r.p50_us:8.1f} "
                  f"{r.p99_us:10.1f}")
            rows.append(csv_row(f"table1/{wl}/{dist}", r.p50_us,
                                f"mops={r.mops:.3f};p99us={r.p99_us:.1f}"))
    return rows


def fig10_11_breakdown(skew: float, label: str, n_ops=6_144):
    """Fig 10 (skew=0.99) / Fig 11 (uniform): technique ladder."""
    rows = []
    print(f"\n== Fig {label}: technique breakdown (skew={skew}) ==")
    print(f"{'config':14s}{'workload':18s} {'Mops':>8s} {'p50us':>8s} "
          f"{'p99us':>10s}")
    for wl in ("write-only", "write-intensive", "read-intensive"):
        base = None
        for name, feat in ABLATION_LADDER:
            _, r = _run(feat, skew, wl, n_ops)
            base = base or r.mops
            print(f"{name:14s}{wl:18s} {r.mops:8.2f} {r.p50_us:8.1f} "
                  f"{r.p99_us:10.1f}")
            rows.append(csv_row(
                f"fig{label}/{wl}/{name}", r.p50_us,
                f"mops={r.mops:.3f};p99us={r.p99_us:.1f};"
                f"speedup={r.mops / base:.2f}"))
    return rows


def fig12_range(n_ops=2_048):
    """Fig 12: range query (range-only + range-write)."""
    rows = []
    print("\n== Fig 12: range query ==")
    for size in (10, 50):
        for wl in ("range-only", "range-write"):
            for feat, nm in ((FG_PLUS, "FG+"), (SHERMAN, "Sherman")):
                _, r = _run(feat, 0.99, wl, n_ops, scan_len=size)
                print(f"{wl} size={size:4d} {nm:8s} mops={r.mops:.2f}")
                rows.append(csv_row(f"fig12/{wl}/{size}/{nm}", r.p50_us,
                                    f"mops={r.mops:.3f}"))
    return rows


def fig13_scalability(n_threads=(128, 256, 512, 1024, 2048)):
    """Fig 13: client threads scaling, uniform + skew (0.99)."""
    rows = []
    print("\n== Fig 13: scalability (write-intensive) ==")
    for skew, nm in ((0.0, "uniform"), (0.9, "skew0.9"), (0.99, "skew0.99")):
        for feat, sysn in ((FG_PLUS, "FG+"), (SHERMAN, "Sherman")):
            for nt in n_threads:
                _, r = _run(feat, skew, "write-intensive", 2 * nt, batch=nt)
                print(f"{nm:9s} {sysn:8s} threads={nt:5d} "
                      f"mops={r.mops:8.2f}")
                rows.append(csv_row(f"fig13/{nm}/{sysn}/{nt}", r.p50_us,
                                    f"mops={r.mops:.3f}"))
    return rows


def fig14_internal(n_ops=6_144):
    """Fig 14: retries, round-trip CDF, write sizes."""
    rows = []
    print("\n== Fig 14: internal metrics (write-intensive, skew 0.99) ==")
    for feat, nm in ((FG_PLUS, "FG+"), (SHERMAN, "Sherman")):
        idx, r = _run(feat, 0.99, "write-intensive", n_ops)
        print(f"{nm:8s} doorbells p50={r.doorbells_p50:.0f} "
              f"p99={r.doorbells_p99:.0f}  "
              f"write-bytes median={r.write_bytes_median:.0f}  "
              f"cas_msgs={idx.counters['cas_msgs']}")
        rows.append(csv_row(
            f"fig14/{nm}", r.p50_us,
            f"doorbells_p50={r.doorbells_p50:.0f};"
            f"doorbells_p99={r.doorbells_p99:.0f};"
            f"write_bytes={r.write_bytes_median:.0f};"
            f"cas={idx.counters['cas_msgs']}"))
    return rows


def fig15_sensitivity():
    """Fig 15: key size and index-cache size sensitivity."""
    import dataclasses
    rows = []
    print("\n== Fig 15a: key size (write-intensive, uniform) ==")
    for kb in (16, 64, 256, 1024):
        for feat, nm in ((FG_PLUS, "FG+"), (SHERMAN, "Sherman")):
            cfg = dataclasses.replace(DEFAULT_CFG, key_bytes=kb, fanout=16)
            _, r = _run(feat, 0.0, "write-intensive", 2_048, cfg=cfg,
                        records=20_000)
            print(f"key={kb:5d}B {nm:8s} mops={r.mops:8.2f}")
            rows.append(csv_row(f"fig15a/key{kb}/{nm}", r.p50_us,
                                f"mops={r.mops:.3f}"))
    print("\n== Fig 15c: index cache size (uniform write-intensive) ==")
    # budgets chosen around the tree's internal-level footprint so the
    # functional cache actually evicts level-1 nodes at the small end
    # (the paper scales cache vs a 1B-key tree; we scale cache vs leaves)
    for cache_kb in (2, 4, 8, 64):
        idx, r = _run(SHERMAN, 0.0, "write-intensive", 12_288,
                      records=8_000, cache_bytes=cache_kb << 10)
        hr = idx.cache.hit_ratio
        print(f"cache={cache_kb:5d}KB mops={r.mops:8.2f} "
              f"hit_ratio={hr:.3f}")
        rows.append(csv_row(f"fig15c/cache{cache_kb}KB", r.p50_us,
                            f"mops={r.mops:.3f};hit={hr:.3f}"))
    return rows


def fig_cache_sweep(n_ops=4_096, records=20_000):
    """Cache-size sweep over the *functional* CS cache (§4.2.3): hit/stale
    rates, remote reads per lookup, and throughput vs cache budget, on a
    read-heavy mix and on a mixed insert workload that goes stale."""
    rows = []
    print("\n== Cache sweep: CS index cache (read-intensive vs ycsb-d) ==")
    print(f"{'workload':16s} {'cacheKB':>8s} {'Mops':>8s} {'hit%':>7s} "
          f"{'stale':>6s} {'rd/lookup':>10s}")
    for wl in ("read-intensive", "ycsb-d"):
        for cache_kb in (0, 16, 64, 256, 4096):
            idx, r = _run(SHERMAN, 0.99, wl, n_ops, records=records,
                          cache_bytes=cache_kb << 10)
            print(f"{wl:16s} {cache_kb:8d} {r.mops:8.2f} "
                  f"{100 * r.cache_hit_rate:7.1f} {r.cache_stale:6d} "
                  f"{r.reads_per_lookup:10.2f}")
            rows.append(csv_row(
                f"figcache/{wl}/{cache_kb}KB", r.p50_us,
                f"mops={r.mops:.3f};hit={r.cache_hit_rate:.3f};"
                f"stale={r.cache_stale};rdl={r.reads_per_lookup:.2f}"))
    return rows


def ablation_sweep(n_ops=4_096, records=20_000,
                   json_path="BENCH_ablation.json"):
    """Fig. 10/11 technique ladder on a write-heavy YCSB-A batch, replayed
    through the verb-trace plane, plus the single-feature negations of
    full Sherman (`sherman-nocombine`, `sherman-flat`).

    Writes ``BENCH_ablation.json`` — the seed of the repo's perf
    trajectory: the ladder order, per-system Mops/p99, and the verb/
    doorbell totals that make the combine/hierarchy wins auditable.
    """
    import dataclasses as _dc

    from repro.workloads import get_preset, run_systems, write_json
    rows = []
    ladder = [nm.lower() for nm, _ in ABLATION_LADDER]
    systems = ladder + ["sherman-nocombine", "sherman-flat"]
    spec = get_preset("ycsb-a", ops=n_ops, load_records=records)
    results = run_systems(spec, systems)
    # the ladder's last rung *is* full Sherman — alias it instead of
    # paying a second identical build + run
    sherman = _dc.replace(results[len(ladder) - 1], system="sherman")
    results.insert(len(ladder), sherman)
    print("\n== Ablation sweep (YCSB-A, verb plane) ==")
    print(f"{'system':18s} {'Mops':>8s} {'p99us':>10s} {'verbs':>9s} "
          f"{'dbells':>9s} {'saved':>7s}")
    for r in results:
        print(f"{r.system:18s} {r.mops:8.2f} {r.p99_us:10.1f} "
              f"{r.verbs:9d} {r.doorbells:9d} {r.doorbells_saved:7d}")
        rows.append(csv_row(
            f"ablation/{r.system}", r.p50_us,
            f"mops={r.mops:.3f};p99us={r.p99_us:.1f};"
            f"doorbells={r.doorbells};saved={r.doorbells_saved}"))
    write_json(json_path, spec, results, extra={"ladder": ladder})
    print(f"wrote {json_path}")
    return rows


def scaling_sweep(client_counts=(8, 16, 32, 64), n_ops=512,
                  records=8_000, json_path="BENCH_scaling.json",
                  partitioned=False):
    """Client-scaling sweep through the multi-CS cluster plane (§5 /
    Fig. 13, now *simulated* rather than lane-labelled): for each client
    count, a fleet of compute servers with private caches and lock
    tables hammers the shared memory pool, and per-CS verb traces merge
    into one contended timeline (DESIGN.md §11).

    Writes ``BENCH_scaling.json`` — the cluster acceptance artifact: one
    RunResult per (system, n_clients) with the per-CS breakdown and the
    merged-trace conservation flag.  The headline curve is SHERMAN's
    write-heavy advantage *growing* with client count while FG+'s atomic
    unit saturates.
    """
    from repro.workloads import get_preset, run_cluster_systems, write_json
    rows = []
    systems = ("sherman", "fg+")
    spec = get_preset("write-intensive", theta=0.99, ops=n_ops,
                      load_records=records)
    results = []
    print("\n== Client scaling (cluster plane, write-intensive 0.99) ==")
    print(f"{'clients':>8s} {'system':10s} {'Mops':>8s} {'p99us':>9s} "
          f"{'stale':>6s} {'xCS':>5s} {'cons':>5s}")
    for nc in client_counts:
        for r in run_cluster_systems(spec, systems, n_clients=nc,
                                     partitioned=partitioned):
            stale = sum(p["cache_stale"] for p in r.per_cs)
            print(f"{r.n_clients:8d} {r.system:10s} {r.mops:8.2f} "
                  f"{r.p99_us:9.1f} {stale:6d} "
                  f"{r.counters['cross_cs_conflicts']:5d} "
                  f"{'OK' if r.conservation_ok else 'BAD':>5s}")
            rows.append(csv_row(
                f"scaling/{r.system}/{r.n_clients}", r.p50_us,
                f"mops={r.mops:.3f};p99us={r.p99_us:.1f};"
                f"conservation={r.conservation_ok}"))
            results.append(r)
    write_json(json_path, spec, results,
               extra={"client_counts": [r.n_clients for r in
                                        results[::len(systems)]],
                      "systems": list(systems),
                      "partitioned": partitioned})
    print(f"wrote {json_path}")
    return rows


def load_sweep_bench(n_ops=2_048, records=8_000, n_clients=16,
                     preset="write-intensive", arrival="poisson",
                     json_path="BENCH_load.json"):
    """Open-loop load sweep through the serving plane (DESIGN.md §12):
    latency vs offered load, queueing delay separated from service time,
    SLO attainment, and max-sustainable-load per system.

    Writes ``BENCH_load.json`` — the serving-plane acceptance artifact:
    per (system, offered rate) one RunResult whose sojourn p99 bends up
    and whose ``sustained_frac`` collapses past each system's knee, plus
    the self-calibrated ``capacity_mops`` / ``max_sustainable_mops``
    summary.  The headline is SHERMAN sustaining a higher offered load
    than FG+ on the write-heavy mix.
    """
    from repro.serve import load_sweep
    payload = load_sweep(preset, arrival=arrival, n_clients=n_clients,
                         load_records=records, ops=n_ops, out=json_path)
    rows = []
    print(f"\n== Load sweep ({preset}, {arrival}, "
          f"{n_clients} clients) ==")
    print(f"{'system':10s} {'offered':>8s} {'p50us':>8s} {'p99us':>9s} "
          f"{'queue':>7s} {'svc':>6s} {'slo%':>6s} {'sust%':>6s}")
    for r in payload["results"]:
        print(f"{r['system']:10s} {r['offered_mops']:8.3f} "
              f"{r['p50_us']:8.2f} {r['p99_us']:9.2f} "
              f"{r['queue_mean_us']:7.2f} {r['service_mean_us']:6.2f} "
              f"{100 * r['slo_attainment']:6.1f} "
              f"{100 * r['sustained_frac']:6.1f}")
        rows.append(csv_row(
            f"load/{r['system']}/{r['offered_mops']:.3f}", r["p50_us"],
            f"p99us={r['p99_us']:.2f};queue_us={r['queue_mean_us']:.2f};"
            f"sustained={r['sustained_frac']:.3f}"))
    for name, cap in payload["capacity_mops"].items():
        print(f"  {name}: closed capacity {cap:.3f} Mops, max sustainable "
              f"{payload['max_sustainable_mops'][name]:.3f} Mops")
    print(f"wrote {json_path}")
    return rows


def throughput_sweep(op_counts=(4_096, 16_384, 65_536), records=60_000,
                     systems=("sherman", "fg+"), warmup_ops=2_048,
                     json_path="BENCH_throughput.json"):
    """Harness-performance sweep: wall-clock sim-ops/s and XLA compile
    counts vs. op count on YCSB-A (the PR 5 shape-stability acceptance).

    Each system warms its jit caches with a ``warmup_ops`` pass on a
    fresh index, then runs the measured op counts on the same index —
    bucketed dispatch means the measured passes must trigger (almost) no
    fresh compilations.  Writes ``BENCH_throughput.json``: per (system,
    n_ops) wall time, sim-ops/s (wall-clock harness throughput — the
    ~372 ops/s pre-PR-5 baseline is recorded for trend), compiles during
    warmup and measurement, plus the simulated Mops/p99 so perf changes
    in either plane are auditable.
    """
    import json as _json
    import time as _time

    from repro.workloads import SYSTEMS, get_preset, run_workload
    from repro.workloads.jitstats import count_compiles

    rows, results = [], []
    spec = get_preset("ycsb-a", load_records=records)
    print("\n== Throughput sweep (harness wall-clock, YCSB-A) ==")
    print(f"{'system':10s} {'ops':>7s} {'wall_s':>8s} {'ops/s':>9s} "
          f"{'warm.c':>7s} {'meas.c':>7s} {'simMops':>8s}")
    for system in systems:
        idx = build_index(SYSTEMS[system.lower()], DEFAULT_CFG,
                          records=records)
        with count_compiles() as warm:
            run_workload(idx, spec.replace(ops=warmup_ops), seed=7,
                         system=system)
        for n_ops in op_counts:
            with count_compiles() as meas:
                t0 = _time.perf_counter()
                r = run_workload(idx, spec.replace(ops=n_ops), seed=1,
                                 system=system)
                wall = _time.perf_counter() - t0
            entry = dict(system=system, n_ops=n_ops, wall_s=wall,
                         sim_ops_per_s=n_ops / wall,
                         compiles_warmup=warm.count,
                         compiles_measured=meas.count,
                         compile_counter_available=meas.available,
                         mops_sim=r.mops, p99_us=r.p99_us)
            results.append(entry)
            print(f"{system:10s} {n_ops:7d} {wall:8.2f} "
                  f"{entry['sim_ops_per_s']:9.0f} {warm.count:7d} "
                  f"{meas.count:7d} {r.mops:8.2f}")
            rows.append(csv_row(
                f"throughput/{system}/{n_ops}", 1e6 * wall / n_ops,
                f"ops_per_s={entry['sim_ops_per_s']:.0f};"
                f"compiles={meas.count}"))
    total_ops = sum(e["n_ops"] for e in results)
    total_wall = sum(e["wall_s"] for e in results)
    payload = dict(workload=spec.name, records=records,
                   batch=spec.batch, warmup_ops=warmup_ops,
                   baseline_ops_per_s=372,        # pre-PR-5 harness speed
                   aggregate_ops_per_s=total_ops / total_wall,
                   results=results)
    with open(json_path, "w") as f:
        _json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {json_path} "
          f"(aggregate {payload['aggregate_ops_per_s']:.0f} ops/s)")
    return rows


def fig16_hocl(n_locks=1_024, n_threads=1_024):
    """Fig 16: HOCL microbenchmark — lock/unlock on a skewed pattern.

    Modeled through the lock plane only (hocl group stats + netsim CAS
    pricing), matching the paper's lock-table microbenchmark."""
    import jax.numpy as jnp

    from repro.core import hocl
    from repro.core.tree import TreeConfig
    from repro.workloads import zipf_keys
    rows = []
    net = NetConfig()
    cfg = TreeConfig(n_ms=1, nodes_per_ms=n_locks, fanout=4,
                     n_locks_per_ms=n_locks, n_cs=8)
    rng = np.random.default_rng(5)
    locks = (zipf_keys(rng, n_threads, n_locks, 0.99) % n_locks
             ).astype(np.int32)
    cs = (np.arange(n_threads) * 8 // n_threads).astype(np.int32)
    g = hocl.group_by_node(cfg, jnp.asarray(locks), jnp.asarray(cs),
                           jnp.ones(n_threads, bool))
    node_rank = np.asarray(g.node_rank)
    node_size = np.asarray(g.node_size)
    local_rank = np.asarray(g.local_rank)
    print("\n== Fig 16: HOCL microbenchmark ==")
    configs = [
        ("baseline", False, False),     # host-memory CAS, no hierarchy
        ("+on-chip", True, False),
        ("+hierarchical", True, True),
    ]
    base = None
    for nm, onchip, hier in configs:
        cas = net.cas_onchip_s if onchip else net.cas_pcie_s
        if hier:
            attempts = (local_rank % (net.handover_max + 1) == 0)
            wait = node_rank * cas
            lat = attempts * net.rtt_s + wait
        else:
            attempts = 1 + node_rank
            wait = node_rank * (cas + net.rtt_s * 0.5)
            lat = net.rtt_s + wait
        hot = float(node_size.max()) * cas * \
            (1 if hier else float(node_size.max()) * 0.1 + 1)
        makespan = max(float(attempts.sum()) / (110e6 if onchip else 2e6),
                       hot, float(np.median(lat)))
        mops = n_threads / makespan / 1e6
        base = base or mops
        print(f"{nm:14s} mops={mops:9.2f} p50={np.percentile(lat, 50) * 1e6:7.2f}us "
              f"p99={np.percentile(lat, 99) * 1e6:8.2f}us "
              f"({mops / base:.2f}x)")
        rows.append(csv_row(f"fig16/{nm}",
                            float(np.percentile(lat, 50)) * 1e6,
                            f"mops={mops:.2f};x={mops / base:.2f}"))
    return rows


def chaos_sweep_bench(records=6_000, n_ops=4_096, n_clients=16,
                      json_path="BENCH_chaos.json"):
    """Chaos sweep through the fault-injection plane (DESIGN.md §13):
    per system, a calibrated fault-free run then the standard five-event
    schedule (MS crash with memory loss, CS leave/join, hot-key storm
    in/out), reporting degraded throughput, SLO violations in the fault
    window and time-to-recover, with the differential-oracle and
    conservation audits inline.

    Writes ``BENCH_chaos.json`` — the recovery acceptance artifact
    scripts/ci.sh gates on (finite TTR and positive degraded throughput
    for every fault, both systems, oracle + conservation green).
    """
    from repro.chaos import chaos_sweep
    payload = chaos_sweep(records=records, ops=n_ops, n_clients=n_clients,
                          out=json_path)
    rows = []
    print(f"\n== Chaos sweep ({payload['preset']}, {n_clients} clients, "
          f"{len(payload['schedules'][payload['results'][0]['system']])} "
          f"faults) ==")
    print(f"{'system':8s} {'fault':10s} {'t_fault':>9s} {'ttr_ms':>8s} "
          f"{'degMops':>8s} {'slo%':>6s}")
    for r in payload["results"]:
        flags = (f"oracle={'OK' if r['oracle_ok'] else 'FAIL'} "
                 f"conserv={'OK' if r['conservation_ok'] else 'FAIL'} "
                 f"glt={'clean' if r['glt_clean'] else 'DIRTY'}")
        for f in r["faults"]:
            ttr = f["ttr_s"]
            print(f"{r['system']:8s} {f['kind']:10s} "
                  f"{f['t_fault_s'] * 1e3:9.3f} "
                  f"{(ttr or 0) * 1e3:8.3f} "
                  f"{f['degraded_mops'] or 0:8.3f} "
                  f"{100 * (f['slo_violation_frac'] or 0):6.1f}")
            rows.append(csv_row(
                f"chaos/{r['system']}/{f['kind']}",
                (ttr or 0) * 1e6,
                f"degraded_mops={f['degraded_mops'] or 0:.4f};"
                f"baseline_mops={r['baseline_mops']:.4f}"))
        print(f"  {r['system']}: baseline {r['baseline_mops']:.3f} Mops, "
              f"{flags}")
    print(f"wrote {json_path}")
    return rows


def obs_sweep(n_ops=1_024, records=8_000, tail_k=64,
              json_path="BENCH_obs.json"):
    """Observability sweep through the recording plane (DESIGN.md §14):
    the full ablation ladder replayed with a :class:`repro.obs.Recorder`
    attached, on a deliberately contended write-heavy batch (zipfian
    0.99, two memory servers) where the lock chains are deep enough for
    tail forensics to have something to say.

    Per rung it reports the p99 tail's exact latency attribution
    (nic_queue / atomic_ser / lock_wait / service, from the
    critical-path walk), the all-ops attribution, the span-conservation
    verdict and the maximum integer residual.

    Writes ``BENCH_obs.json`` — the tail-forensics acceptance artifact
    scripts/ci.sh gates on: zero residual and green span accounting on
    every rung, and the HOCL story made quantitative — enabling the
    hierarchical lock shifts the tail's attribution out of
    lock-protocol wait and into NIC/data time (Fig. 10/11, per op).
    """
    import dataclasses as _dc

    from repro.core.tree import TreeConfig
    from repro.workloads import get_preset, run_systems, write_json

    cfg = TreeConfig(n_ms=2, nodes_per_ms=8_192, fanout=16,
                     n_locks_per_ms=4_096, max_height=7, n_cs=8)
    ladder = [nm.lower() for nm, _ in ABLATION_LADDER]
    spec = get_preset("write-intensive", theta=0.99, ops=n_ops,
                      batch=max(128, n_ops // 2), load_records=records)
    recorders = {}
    results = run_systems(spec, ladder, cfg, recorders=recorders,
                          tail_k=tail_k)
    # the ladder's last rung *is* full Sherman — alias it
    results.append(_dc.replace(results[-1], system="sherman"))
    rows = []
    print(f"\n== Observability sweep (write-intensive 0.99, "
          f"{cfg.n_ms} MS, tail_k={tail_k}) ==")
    print(f"{'system':14s} {'p99us':>9s} {'nic%':>6s} {'atom%':>6s} "
          f"{'lock%':>6s} {'svc%':>6s} {'resid':>6s} {'spans':>6s}")
    for r in results:
        t = r.obs["tail_attribution"]
        print(f"{r.system:14s} {r.p99_us:9.1f} "
              f"{100 * t['nic_queue_frac']:6.1f} "
              f"{100 * t['atomic_ser_frac']:6.1f} "
              f"{100 * t['lock_wait_frac']:6.1f} "
              f"{100 * t['service_frac']:6.1f} "
              f"{r.obs['attr_residual_ps']:6d} "
              f"{'OK' if r.obs['spans_ok'] else 'BAD':>6s}")
        rows.append(csv_row(
            f"obs/{r.system}", r.p99_us,
            f"lock={t['lock_wait_frac']:.3f};"
            f"nic={t['nic_queue_frac']:.3f};"
            f"atomic={t['atomic_ser_frac']:.3f};"
            f"service={t['service_frac']:.3f};"
            f"residual_ps={r.obs['attr_residual_ps']};"
            f"spans_ok={r.obs['spans_ok']}"))
    write_json(json_path, spec, results,
               extra={"kind": "obs", "ladder": ladder, "tail_k": tail_k,
                      "n_ms": cfg.n_ms})
    print(f"wrote {json_path}")
    return rows
