"""Benchmark driver: one function per paper table/figure.

All figures run on the unified workload engine (:mod:`repro.workloads`).
Prints ``name,us_per_call,derived`` CSV rows (stdout) plus human tables;
``--quick`` shrinks op counts for CI-speed runs and ``--json`` writes the
rows to a ``BENCH_*.json`` file.
"""
from __future__ import annotations

import argparse
import json


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller op counts (CI)")
    ap.add_argument("--only", default="",
                    help="comma list: table1,fig10,fig11,fig12,fig13,"
                         "fig14,fig15,fig16,cache,ablation,scaling,"
                         "throughput,load,chaos,obs")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows to PATH (default "
                         "BENCH_paper_figs.json with --json '')")
    args = ap.parse_args(argv)
    from benchmarks import paper_figs as F

    n = 2_048 if args.quick else 6_144
    sel = set(args.only.split(",")) if args.only else None
    rows = []

    def want(name):
        return sel is None or name in sel

    if want("table1"):
        rows += F.table1_one_sided(n_ops=n)
    if want("fig10"):
        rows += F.fig10_11_breakdown(0.99, "10", n_ops=n)
    if want("fig11"):
        rows += F.fig10_11_breakdown(0.0, "11", n_ops=n)
    if want("fig12"):
        rows += F.fig12_range(n_ops=max(512, n // 4))
    if want("fig13"):
        threads = (128, 512, 2048) if args.quick else \
            (128, 256, 512, 1024, 2048)
        rows += F.fig13_scalability(threads)
    if want("fig14"):
        rows += F.fig14_internal(n_ops=n)
    if want("fig15"):
        rows += F.fig15_sensitivity()
    if want("fig16"):
        rows += F.fig16_hocl()
    if want("cache"):
        rows += F.fig_cache_sweep(n_ops=max(1_024, n // 2))
    if want("ablation"):
        # verb-plane ladder; always writes BENCH_ablation.json (the perf
        # trajectory seed), independent of --json.  Since the PR 5
        # shape-stable hot path the full sweep runs at paper-ish scale.
        rows += F.ablation_sweep(n_ops=4_096 if args.quick else 65_536,
                                 records=8_000 if args.quick else 20_000)
    if want("scaling"):
        # multi-CS cluster plane; always writes BENCH_scaling.json (the
        # client-scaling acceptance curve), independent of --json
        rows += F.scaling_sweep(
            client_counts=(8, 16, 32, 64),
            n_ops=2_048 if args.quick else 32_768,
            records=8_000 if args.quick else 20_000)
    if want("load"):
        # open-loop serving plane; always writes BENCH_load.json (the
        # latency-vs-offered-load acceptance curve), independent of --json
        rows += F.load_sweep_bench(
            n_ops=1_024 if args.quick else 8_192,
            records=4_000 if args.quick else 20_000,
            n_clients=16)
    if want("chaos"):
        # fault-injection plane; always writes BENCH_chaos.json (the
        # recovery acceptance artifact), independent of --json
        rows += F.chaos_sweep_bench(
            records=4_000 if args.quick else 8_000,
            n_ops=2_048 if args.quick else 8_192,
            n_clients=8 if args.quick else 16)
    if want("obs"):
        # observability plane; always writes BENCH_obs.json (the
        # tail-forensics acceptance artifact: exact attribution +
        # span conservation per ladder rung)
        rows += F.obs_sweep(n_ops=1_024 if args.quick else 4_096,
                            records=8_000 if args.quick else 20_000)
    if want("throughput"):
        # harness-performance sweep; always writes BENCH_throughput.json
        # (wall-clock sim-ops/s + XLA compile counts — the PR 5 gate)
        rows += F.throughput_sweep(
            op_counts=(65_536,) if args.quick else (4_096, 16_384, 65_536),
            records=8_000 if args.quick else 60_000)

    print("\n# CSV")
    for r in rows:
        print(r)

    if args.json is not None:
        path = args.json or "BENCH_paper_figs.json"
        payload = []
        for r in rows:
            name, us, derived = r.split(",", 2)
            payload.append({"name": name, "us_per_call": float(us),
                            "derived": derived})
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
