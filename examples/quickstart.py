"""Quickstart: the Sherman index + a tiny LM in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import ShermanIndex, TreeConfig, SHERMAN, FG_PLUS

# --- 1. build a disaggregated B+Tree over 4 memory servers ---------------
cfg = TreeConfig(n_ms=4, nodes_per_ms=2048, fanout=16, n_cs=4)
rng = np.random.default_rng(0)
keys = rng.choice(1 << 20, size=20_000, replace=False)
vals = rng.integers(0, 1 << 30, size=20_000)
idx = ShermanIndex.build(cfg, keys, vals, features=SHERMAN)

# --- 2. batched ops (a batch lane == a client thread) --------------------
idx.insert(np.asarray([7, 8, 9]), np.asarray([70, 80, 90]))
got, found = idx.lookup(np.asarray([7, 8, 9, 123456789 % (1 << 20)]))
print("lookup:", got[:3], "found:", found[:3])

rk, rv, rn = idx.range(np.asarray([0]), count=5, max_leaves=10)
print("first 5 keys:", rk[0][: rn[0]])

# --- 3. the same workload on the FG+ baseline (§3.1) ---------------------
fg = ShermanIndex.build(cfg, keys, vals, features=FG_PLUS)
hot = np.full(512, 42)                     # everyone hammers one key
fg.insert(hot, np.arange(512))
idx.insert(hot, np.arange(512))
print(f"skewed write p99: FG+ {fg.latency_percentiles()[99]:.0f}us  "
      f"Sherman {idx.latency_percentiles()[99]:.0f}us  "
      f"(handovers: {idx.counters['handovers']})")

# --- 4. a tiny LM training run on the same framework ---------------------
from repro.configs import get_reduced
from repro.launch.train import TrainConfig, run
from repro.models.registry import build
from repro.optim.adamw import AdamWConfig

api = build(get_reduced("smollm-135m"))
out = run(api, TrainConfig(steps=10, ckpt_every=100, log_every=5,
                           ckpt_dir="/tmp/quickstart_ckpt",
                           opt=AdamWConfig(lr=1e-3, warmup_steps=2,
                                           total_steps=10)),
          batch_size=2, seq=32, verbose=True)
print(f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")
