"""Paged-KV serving with the Sherman index as the page table.

This is where the paper's technique plugs into the LM framework
(DESIGN.md §2): KV pages of in-flight sequences live in a disaggregated
page pool; the *page table* mapping ``(seq_id, page_no) -> page slot`` is a
Sherman B+Tree, manipulated with the paper's batched ops:

* admit a sequence  -> ``insert`` a page-table entry per allocated page
* decode step       -> batched ``lookup`` of every sequence's current page
* evict a sequence  -> ``delete`` its entries (+ ``range`` scan per seq —
  the ordered index gives us per-sequence page enumeration for free)

    PYTHONPATH=src python examples/serve_paged.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core import ShermanIndex, TreeConfig, SHERMAN
from repro.models.registry import build, make_batch

PAGE = 16               # tokens per KV page


def page_key(seq_id: int, page_no: int) -> int:
    return seq_id * 4096 + page_no      # ordered: seq's pages are adjacent


def main():
    cfg = get_reduced("smollm-135m")
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))

    n_seqs, steps = 8, 48
    table = ShermanIndex.build(
        TreeConfig(n_ms=2, nodes_per_ms=1024, fanout=16, n_cs=2),
        np.zeros(0, np.int32), np.zeros(0, np.int32), features=SHERMAN)
    free_pages = list(range(4096))

    batch = make_batch(cfg, batch=n_seqs, seq=1)
    state = api.decode_init(params, batch, s_max=64)
    tok = batch["tokens"][:, 0]

    for step in range(steps):
        # allocate a new page for every sequence crossing a page boundary
        if step % PAGE == 0:
            page_no = step // PAGE
            keys = np.asarray([page_key(s, page_no)
                               for s in range(n_seqs)], np.int32)
            slots = np.asarray([free_pages.pop() for _ in range(n_seqs)],
                               np.int32)
            table.insert(keys, slots)
        # look up each sequence's current page slot (batched, lock-free)
        cur = np.asarray([page_key(s, step // PAGE)
                          for s in range(n_seqs)], np.int32)
        slots, found = table.lookup(cur)
        assert found.all()
        logits, state = jax.jit(api.decode_step)(params, state, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)

    # sequence 3 finishes: enumerate + free its pages via range scan
    rk, rv, rn = table.range(np.asarray([page_key(3, 0)], np.int32),
                             count=steps // PAGE, max_leaves=8)
    mine = [(int(k), int(v)) for k, v in zip(rk[0][:rn[0]], rv[0][:rn[0]])
            if k // 4096 == 3]
    table.delete(np.asarray([k for k, _ in mine], np.int32))
    free_pages.extend(v for _, v in mine)

    print(f"served {steps} decode steps for {n_seqs} seqs")
    print(f"page-table ops: {table.counters['write_ops']} writes, "
          f"{table.counters['read_ops']} lookups, "
          f"p99 lookup {table.latency_percentiles('read')[99]:.1f}us")
    print(f"evicted seq 3: {len(mine)} pages reclaimed "
          f"({len(free_pages)} free)")


if __name__ == "__main__":
    main()
