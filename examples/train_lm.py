"""End-to-end training driver: a ~135M-param LM for a few hundred steps.

Thin CLI over repro.launch.train (checkpoint/resume, straggler watchdog,
optional int8 gradient compression all included):

    # fast CPU demo (reduced config):
    PYTHONPATH=src python examples/train_lm.py --steps 300 --reduced

    # the real smollm-135m (sized for a TPU host):
    PYTHONPATH=src python examples/train_lm.py --steps 300 \
        --batch 32 --seq 1024
"""
from repro.launch.train import main

if __name__ == "__main__":
    main()
