"""YCSB workloads on the Sherman index — the paper's own evaluation loop.

    PYTHONPATH=src:. python examples/ycsb_index.py \
        --workload write-intensive --skew 0.99 --system sherman --ops 4096
"""
import argparse
import sys

sys.path.insert(0, ".")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="write-intensive",
                    choices=["write-only", "write-intensive",
                             "read-intensive", "range-only"])
    ap.add_argument("--skew", type=float, default=0.99)
    ap.add_argument("--system", default="sherman",
                    choices=["sherman", "fg+"])
    ap.add_argument("--ops", type=int, default=4_096)
    ap.add_argument("--batch", type=int, default=1_024)
    args = ap.parse_args()

    from benchmarks.common import build_index, run_mix
    from repro.core.netsim import FG_PLUS, SHERMAN

    feat = SHERMAN if args.system == "sherman" else FG_PLUS
    idx = build_index(feat)
    read_frac = {"write-only": 0.0, "write-intensive": 0.5,
                 "read-intensive": 0.95, "range-only": 0.0}[args.workload]
    range_frac = 1.0 if args.workload == "range-only" else 0.0
    r = run_mix(idx, read_frac=read_frac, skew=args.skew,
                n_ops=args.ops, batch=args.batch,
                range_frac=range_frac, range_size=10)
    print(f"{args.system} {args.workload} skew={args.skew}: "
          f"{r.mops:.2f} Mops  p50={r.p50_us:.1f}us  p99={r.p99_us:.1f}us")
    print("counters:", {k: v for k, v in r.counters.items()
                        if not k.startswith("sim")})


if __name__ == "__main__":
    main()
