"""YCSB workloads on the Sherman index — the paper's own evaluation loop.

All mixes come from the unified engine (``repro.workloads``); this example
is just a thin invocation of it.  Equivalent CLI::

    PYTHONPATH=src python -m repro.workloads --preset write-intensive \
        --skew 0.99 --systems sherman

    PYTHONPATH=src:. python examples/ycsb_index.py \
        --workload ycsb-a --skew 0.99 --system sherman --ops 4096
"""
import argparse
import sys

sys.path.insert(0, ".")


def main():
    from repro.workloads import (PRESETS, SYSTEMS, build_index, get_preset,
                                 run_workload)

    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="write-intensive",
                    choices=sorted(PRESETS))
    ap.add_argument("--skew", type=float, default=0.99)
    ap.add_argument("--system", default="sherman",
                    choices=sorted(SYSTEMS))
    ap.add_argument("--ops", type=int, default=4_096)
    ap.add_argument("--batch", type=int, default=1_024)
    args = ap.parse_args()

    spec = get_preset(args.workload, theta=args.skew, ops=args.ops,
                      batch=args.batch)
    idx = build_index(SYSTEMS[args.system], records=spec.load_records)
    r = run_workload(idx, spec, system=args.system)
    print(f"{args.system} {args.workload} skew={args.skew}: "
          f"{r.mops:.2f} Mops  p50={r.p50_us:.1f}us  p99={r.p99_us:.1f}us")
    print("ops:", r.op_counts)
    print("counters:", {k: v for k, v in r.counters.items()
                        if not k.startswith("sim")})


if __name__ == "__main__":
    main()
