#!/usr/bin/env python
"""Guard the checked-in BENCH artifacts against silent perf regressions.

scripts/ci.sh regenerates ``BENCH_ablation.json``, ``BENCH_load.json``,
``BENCH_chaos.json`` and ``BENCH_obs.json`` in the working tree; this
script diffs those fresh numbers against the *committed* baselines
(``git show HEAD:<file>``) and fails when any matched point regresses by
more than ``--threshold`` (default 20%): tail latency up, or
throughput / sustainable load down.

Points are matched by identity — system name plus, where applicable, the
offered rate or fault kind — so schedule or sweep-shape changes surface
as explicit SKIP notes instead of bogus comparisons.  A file is skipped
(with a note) when it is absent from HEAD (a brand-new artifact) or when
its sweep scale (op count / record count) differs from the baseline's —
quick-mode and full-mode runs are not comparable.

Exit codes: 0 clean (or everything skipped), 1 regression, 2 bad usage.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys

FILES = ("BENCH_ablation.json", "BENCH_load.json", "BENCH_chaos.json",
         "BENCH_obs.json")

#: metric direction: True when larger values are worse (latency-like)
LARGER_IS_WORSE = {"p99_us": True, "mops": False, "baseline_mops": False,
                   "degraded_mops": False, "max_sustainable_mops": False}


def _scale(doc: dict) -> tuple:
    """The sweep's identity scale; comparisons across scales are bogus."""
    spec = doc.get("spec", {})
    return (spec.get("ops", doc.get("ops")),
            spec.get("load_records", doc.get("records")),
            doc.get("n_clients"))


def _points(path: str, doc: dict) -> dict:
    """Flatten one BENCH document into {(point-id, metric): value}."""
    out = {}
    kind = doc.get("kind")
    if kind == "load_sweep":
        for r in doc["results"]:
            pid = (r["system"], round(r["offered_mops"], 6))
            out[(pid, "p99_us")] = r["p99_us"]
        for s, v in doc["max_sustainable_mops"].items():
            out[((s,), "max_sustainable_mops")] = v
    elif kind == "chaos":
        for r in doc["results"]:
            out[((r["system"],), "baseline_mops")] = r["baseline_mops"]
            for f in r["faults"]:
                if f.get("degraded_mops"):
                    out[((r["system"], f["kind"]), "degraded_mops")] = \
                        f["degraded_mops"]
    else:                              # ablation / obs: plain result rows
        for r in doc["results"]:
            out[((r["system"],), "p99_us")] = r["p99_us"]
            out[((r["system"],), "mops")] = r["mops"]
    return out


def _baseline(path: str) -> dict | None:
    try:
        blob = subprocess.run(["git", "show", f"HEAD:{path}"],
                              capture_output=True, text=True, check=True)
    except (subprocess.CalledProcessError, OSError):
        return None
    return json.loads(blob.stdout)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail on >threshold p99/throughput regressions in "
                    "fresh BENCH files vs the committed baselines")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="allowed relative regression (default 0.20)")
    ap.add_argument("--files", nargs="*", default=list(FILES))
    args = ap.parse_args(argv)
    if not 0 < args.threshold < 10:
        ap.error(f"--threshold out of range: {args.threshold}")

    bad = []
    compared = 0
    for path in args.files:
        try:
            fresh_doc = json.load(open(path))
        except FileNotFoundError:
            print(f"SKIP {path}: missing from the working tree "
                  f"(generate it first — ci.sh does)")
            continue
        base_doc = _baseline(path)
        if base_doc is None:
            print(f"SKIP {path}: no committed baseline in HEAD")
            continue
        if _scale(base_doc) != _scale(fresh_doc):
            print(f"SKIP {path}: sweep scale changed "
                  f"{_scale(base_doc)} -> {_scale(fresh_doc)}")
            continue
        base, fresh = _points(path, base_doc), _points(path, fresh_doc)
        for key in sorted(base.keys() - fresh.keys()):
            print(f"SKIP {path}: point {key} gone from the fresh run")
        for key in sorted(base.keys() & fresh.keys()):
            (pid, metric), b, f = key, base[key], fresh[key]
            if not (b and f):
                continue
            compared += 1
            ratio = f / b
            worse = (ratio > 1 + args.threshold
                     if LARGER_IS_WORSE[metric]
                     else ratio < 1 - args.threshold)
            if worse:
                bad.append(f"{path} {'/'.join(map(str, pid))} {metric}: "
                           f"{b:.4g} -> {f:.4g} ({ratio:.2f}x)")
    if bad:
        print(f"\nREGRESSION ({len(bad)} point(s) past "
              f"{args.threshold:.0%}):")
        for line in bad:
            print("  " + line)
        return 1
    print(f"bench regression check OK: {compared} points within "
          f"{args.threshold:.0%} of the committed baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main())
