#!/usr/bin/env python
"""Fail on docstring/doc cross-references to nonexistent ``repro.*`` modules.

Scans ``src/``, ``docs/``, ``benchmarks/``, ``examples/`` and the README
for dotted ``repro.*`` references and checks each against the real module
tree under ``src/``.  A reference is accepted when it names a module or
package, or an attribute that actually exists on an imported module
(``repro.core.ops.lookup_batch``).

Also verifies the result-schema tables in docs/BENCHMARKS.md against the
code: every field named in the ``results[i]`` table must be a real
``repro.workloads.engine.RunResult`` dataclass field, and every key in
the ``counters`` table must exist in ``ShermanIndex.counters`` — so the
docs can never again drift to pre-rename counter names (the PR 5
``rtts`` -> ``lane_doorbells``/``doorbells_p50`` class of staleness).
Run from the repo root:

    python scripts/check_xrefs.py
"""
from __future__ import annotations

import importlib
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

REF = re.compile(r"repro\.[a-zA-Z_][a-zA-Z_.0-9]*")
SCAN = ("src", "docs", "benchmarks", "examples", "README.md")
EXTS = (".py", ".md")


def _is_py(parts):
    return os.path.isfile(os.path.join("src", *parts) + ".py")


def _is_pkg(parts):
    return os.path.isdir(os.path.join("src", *parts))


def _has_attr(mod_parts, attr) -> bool:
    try:
        module = importlib.import_module(".".join(mod_parts))
    except Exception:
        return False
    return hasattr(module, attr)


def _ok(ref: str) -> bool:
    parts = ref.rstrip(".").split(".")
    if _is_py(parts) or _is_pkg(parts):
        return True
    if len(parts) > 1 and (_is_py(parts[:-1]) or _is_pkg(parts[:-1])):
        # attribute of a module / name exported by a package __init__:
        # import it for real rather than trusting a substring match
        return _has_attr(parts[:-1], parts[-1])
    return False


TOKEN = re.compile(r"`([a-z_][a-z_0-9]*)`")


def _schema_table_fields(path="docs/BENCHMARKS.md"):
    """Backticked names from the first column of the RunResult and
    counters schema tables, keyed by which table they came from."""
    section = None
    fields = {"result": set(), "counter": set()}
    with open(path) as f:
        for line in f:
            if line.startswith("#"):
                if "`results[i]`" in line:
                    section = "result"
                elif "`counters`" in line:
                    section = "counter"
                else:
                    section = None
                continue
            if section and line.startswith("|"):
                first = line.split("|")[1]
                if set(first.strip()) <= {"-"}:     # separator row
                    continue
                fields[section] |= set(TOKEN.findall(first))
    return fields


def _check_schema_tables() -> list:
    import dataclasses

    from repro.core.api import ShermanIndex
    from repro.core.tree import TreeConfig
    from repro.workloads.engine import RunResult

    tables = _schema_table_fields()
    real_fields = {f.name for f in dataclasses.fields(RunResult)}
    tiny = TreeConfig(n_ms=1, nodes_per_ms=64, fanout=4,
                      n_locks_per_ms=16, max_height=3, n_cs=1)
    real_counters = set(ShermanIndex.empty(tiny).counters)
    bad = []
    for name in sorted(tables["result"] - real_fields):
        bad.append(f"docs/BENCHMARKS.md: results[i] schema names "
                   f"{name!r}, which is not a RunResult field")
    for name in sorted(tables["counter"] - real_counters):
        bad.append(f"docs/BENCHMARKS.md: counters schema names "
                   f"{name!r}, which is not in ShermanIndex.counters")
    if not (tables["result"] and tables["counter"]):
        bad.append("docs/BENCHMARKS.md: schema tables not found "
                   "(heading layout changed?)")
    return bad


def main() -> int:
    bad = _check_schema_tables()
    for top in SCAN:
        if os.path.isfile(top):
            files = [top]
        else:
            files = [os.path.join(r, f)
                     for r, _, fs in os.walk(top) for f in fs
                     if f.endswith(EXTS)]
        for path in files:
            with open(path, errors="replace") as f:
                for lineno, line in enumerate(f, 1):
                    for ref in REF.findall(line):
                        if not _ok(ref):
                            bad.append(f"{path}:{lineno}: dangling "
                                       f"cross-reference {ref!r}")
    for b in bad:
        print(b, file=sys.stderr)
    if bad:
        print(f"{len(bad)} dangling repro.* cross-reference(s)",
              file=sys.stderr)
        return 1
    print("xrefs OK: all repro.* references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
