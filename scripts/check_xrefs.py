#!/usr/bin/env python
"""Fail on docstring/doc cross-references to nonexistent ``repro.*`` modules.

Scans ``src/``, ``docs/``, ``benchmarks/``, ``examples/`` and the README
for dotted ``repro.*`` references and checks each against the real module
tree under ``src/``.  A reference is accepted when it names a module or
package, or an attribute that actually exists on an imported module
(``repro.core.ops.lookup_batch``).  Run from the repo root:

    python scripts/check_xrefs.py
"""
from __future__ import annotations

import importlib
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

REF = re.compile(r"repro\.[a-zA-Z_][a-zA-Z_.0-9]*")
SCAN = ("src", "docs", "benchmarks", "examples", "README.md")
EXTS = (".py", ".md")


def _is_py(parts):
    return os.path.isfile(os.path.join("src", *parts) + ".py")


def _is_pkg(parts):
    return os.path.isdir(os.path.join("src", *parts))


def _has_attr(mod_parts, attr) -> bool:
    try:
        module = importlib.import_module(".".join(mod_parts))
    except Exception:
        return False
    return hasattr(module, attr)


def _ok(ref: str) -> bool:
    parts = ref.rstrip(".").split(".")
    if _is_py(parts) or _is_pkg(parts):
        return True
    if len(parts) > 1 and (_is_py(parts[:-1]) or _is_pkg(parts[:-1])):
        # attribute of a module / name exported by a package __init__:
        # import it for real rather than trusting a substring match
        return _has_attr(parts[:-1], parts[-1])
    return False


def main() -> int:
    bad = []
    for top in SCAN:
        if os.path.isfile(top):
            files = [top]
        else:
            files = [os.path.join(r, f)
                     for r, _, fs in os.walk(top) for f in fs
                     if f.endswith(EXTS)]
        for path in files:
            with open(path, errors="replace") as f:
                for lineno, line in enumerate(f, 1):
                    for ref in REF.findall(line):
                        if not _ok(ref):
                            bad.append(f"{path}:{lineno}: dangling "
                                       f"cross-reference {ref!r}")
    for b in bad:
        print(b, file=sys.stderr)
    if bad:
        print(f"{len(bad)} dangling repro.* cross-reference(s)",
              file=sys.stderr)
        return 1
    print("xrefs OK: all repro.* references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
