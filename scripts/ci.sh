#!/usr/bin/env bash
# CI smoke: tier-1 tests + a quick paper-figure run + the workload CLI.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# every gated BENCH artifact must exist before its gate reads it — a
# sweep that silently failed to write its file is a CI bug, not a pass
require_bench() {
    for f in "$@"; do
        if [ ! -s "$f" ]; then
            echo "FATAL: gated benchmark artifact $f is missing or empty" >&2
            exit 1
        fi
    done
}

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== benchmark smoke (Table 1, quick) =="
python benchmarks/run.py --quick --only table1

echo "== verb-trace conservation check =="
python -m pytest -q tests/test_netsim_trace.py -k \
    "conservation or cycle_masks or doorbell"

echo "== vectorized-replay equivalence + compile stability =="
python -m pytest -q tests/test_throughput.py -k \
    "simulate_matches or property_simulate or compiles or bucketing"

echo "== throughput smoke gate (writes BENCH_throughput.json) =="
python benchmarks/run.py --quick --only throughput
require_bench BENCH_throughput.json
python - <<'EOF'
import json, math

d = json.load(open("BENCH_throughput.json"))
assert d["workload"] == "ycsb-a"
# the PR 5 acceptance floor: >= 20x the ~372 ops/s pre-PR-5 harness
assert d["aggregate_ops_per_s"] >= 7_500, d["aggregate_ops_per_s"]
by_sys = {}
for r in d["results"]:
    for k in ("wall_s", "sim_ops_per_s", "mops_sim", "p99_us"):
        assert math.isfinite(r[k]) and r[k] > 0, (r["system"], k, r[k])
    # bucketed dispatch: (almost) nothing compiles after warmup
    if r["compile_counter_available"]:
        assert r["compiles_measured"] <= 8, r
    by_sys.setdefault(r["system"], []).append(r)
assert {"sherman", "fg+"} <= set(by_sys), sorted(by_sys)
big = [r for r in d["results"] if r["n_ops"] >= 65_536]
assert big, "sweep must include the 65536-op acceptance point"
for r in big:
    assert r["sim_ops_per_s"] >= 7_500, (r["system"], r["sim_ops_per_s"])
print("throughput OK:",
      " ".join(f"{r['system']}@{r['n_ops']}={r['sim_ops_per_s']:.0f}ops/s"
               f"(c={r['compiles_measured']})" for r in d["results"]),
      f"| aggregate {d['aggregate_ops_per_s']:.0f} ops/s")
EOF

echo "== ablation sweep (verb plane, writes BENCH_ablation.json) =="
python benchmarks/run.py --quick --only ablation
require_bench BENCH_ablation.json
python - <<'EOF'
import json, math

d = json.load(open("BENCH_ablation.json"))
res = {r["system"]: r for r in d["results"]}
ladder = d["ladder"]
mops = [res[s]["mops"] for s in ladder]
assert all(math.isfinite(m) and m > 0 for m in mops), mops
assert all(b >= 0.98 * a for a, b in zip(mops, mops[1:])), \
    ("ablation ladder regressed", list(zip(ladder, mops)))
sh = res["sherman"]
assert sh["doorbells"] < res["sherman-nocombine"]["doorbells"], \
    (sh["doorbells"], res["sherman-nocombine"]["doorbells"])
assert math.isfinite(sh["p99_us"]) and 0 < sh["p99_us"] < \
    res["sherman-flat"]["p99_us"], \
    (sh["p99_us"], res["sherman-flat"]["p99_us"])
print("ablation OK:", " -> ".join(f"{s}={m:.2f}" for s, m in
                                  zip(ladder, mops)),
      f"| doorbells {sh['doorbells']} < "
      f"{res['sherman-nocombine']['doorbells']}",
      f"| p99 {sh['p99_us']:.1f}us < "
      f"{res['sherman-flat']['p99_us']:.1f}us")
EOF

echo "== cluster scaling sweep (writes BENCH_scaling.json) =="
python benchmarks/run.py --quick --only scaling
require_bench BENCH_scaling.json
python - <<'EOF'
import json, math

d = json.load(open("BENCH_scaling.json"))
systems = d["systems"]
counts = d["client_counts"]
assert len(counts) >= 4, ("need >= 4 client counts", counts)
assert set(systems) == {"sherman", "fg+"}, systems
by = {(r["system"], r["n_clients"]): r for r in d["results"]}
assert len(by) == len(counts) * len(systems), "missing sweep points"
for r in d["results"]:
    # merged-trace verb conservation must hold at every sweep point
    assert r["conservation_ok"], (r["system"], r["n_clients"])
    assert math.isfinite(r["mops"]) and r["mops"] > 0
    assert len(r["per_cs"]) >= 2, "cluster runs must report >= 2 CSs"
    assert sum(p["ops"] for p in r["per_cs"]) >= r["n_ops"]

ratios = [by[("sherman", n)]["mops"] / by[("fg+", n)]["mops"]
          for n in counts]
# SHERMAN >= FG+ write-heavy throughput at max clients, and the
# advantage grows with client count
assert ratios[-1] >= 1.0, ratios
assert ratios[-1] > ratios[0] * 1.02, ("advantage must grow", ratios)
# p99 tail is monotone in client count (queue depth) until saturation
for s in systems:
    p99 = [by[(s, n)]["p99_us"] for n in counts]
    assert all(math.isfinite(p) and p > 0 for p in p99), (s, p99)
    assert all(b >= 0.95 * a for a, b in zip(p99, p99[1:])), (s, p99)
print("scaling OK:",
      " ".join(f"{n}cl={r:.2f}x" for n, r in zip(counts, ratios)),
      "| p99(sherman)",
      "->".join(f"{by[('sherman', n)]['p99_us']:.1f}" for n in counts))
EOF

echo "== open-loop load sweep (serving plane, writes BENCH_load.json) =="
python benchmarks/run.py --quick --only load
require_bench BENCH_load.json
python - <<'EOF'
import json, math

d = json.load(open("BENCH_load.json"))
assert d["kind"] == "load_sweep"
systems = set(d["capacity_mops"])
assert systems == {"sherman", "fg+"}, systems
rates = d["rates_mops"]
assert len(rates) >= 4, ("need >= 4 offered-load points", rates)
by = {}
for r in d["results"]:
    assert r["arrival"] == d["arrival"], r["arrival"]
    # queueing delay must be reported separately from service time
    assert math.isfinite(r["queue_mean_us"]) and r["queue_mean_us"] >= 0
    assert math.isfinite(r["service_mean_us"]) and r["service_mean_us"] > 0
    assert math.isfinite(r["p99_us"]) and r["p99_us"] > 0
    assert 0 <= r["slo_attainment"] <= 1, r["slo_attainment"]
    assert 0 < r["sustained_frac"] <= 1, r["sustained_frac"]
    assert r["conservation_ok"], (r["system"], r["offered_mops"])
    by.setdefault(r["system"], []).append(r)
for s in systems:
    assert len(by[s]) == len(rates), (s, len(by[s]), len(rates))
    # max sustainable load: finite, positive, one of the swept rates
    ms = d["max_sustainable_mops"][s]
    assert math.isfinite(ms) and ms > 0, (s, ms)
    assert any(abs(ms - r) < 1e-9 for r in rates), (s, ms, rates)
# the write-optimized system sustains >= the baseline's offered load
# on the write-heavy preset
assert d["max_sustainable_mops"]["sherman"] >= \
    d["max_sustainable_mops"]["fg+"], d["max_sustainable_mops"]
print("load OK:",
      " ".join(f"{s}: cap={d['capacity_mops'][s]:.2f} "
               f"sustained<={d['max_sustainable_mops'][s]:.2f}Mops"
               for s in sorted(systems)),
      f"| {len(rates)} rates, slo={d['slo_us']:.1f}us")
EOF

echo "== chaos sweep (fault injection, writes BENCH_chaos.json) =="
python benchmarks/run.py --quick --only chaos
require_bench BENCH_chaos.json
python - <<'EOF'
import json, math

d = json.load(open("BENCH_chaos.json"))
assert d["kind"] == "chaos"
systems = {r["system"] for r in d["results"]}
assert systems == {"sherman", "fg+"}, systems
for r in d["results"]:
    # the differential harness must hold under the full schedule
    assert r["oracle_ok"], (r["system"], "differential oracle broken")
    assert r["conservation_ok"], (r["system"], "conservation across crash")
    assert r["glt_clean"], (r["system"], "locks leaked after recovery")
    assert r["unfired_faults"] == 0, (r["system"], r["unfired_faults"])
    assert math.isfinite(r["baseline_mops"]) and r["baseline_mops"] > 0
    assert math.isfinite(r["slo_us"]) and r["slo_us"] > 0
    kinds = {f["kind"] for f in r["faults"]}
    assert {"ms_crash", "cs_leave", "cs_join", "skew_shift"} <= kinds, kinds
    for f in r["faults"]:
        # recovery gate: every fault recovers in finite time with
        # positive throughput inside the degraded window
        assert f["ttr_s"] is not None and math.isfinite(f["ttr_s"]) \
            and f["ttr_s"] >= 0, (r["system"], f["kind"], f["ttr_s"])
        assert f["degraded_mops"] is not None \
            and math.isfinite(f["degraded_mops"]) \
            and f["degraded_mops"] > 0, (r["system"], f["kind"])
        assert 0 <= f["slo_violation_frac"] <= 1, (r["system"], f["kind"])
crash = {r["system"]: [f for f in r["faults"] if f["kind"] == "ms_crash"][0]
         for r in d["results"]}
print("chaos OK:",
      " ".join(f"{s}: crash ttr={c['ttr_s'] * 1e3:.2f}ms "
               f"deg={c['degraded_mops']:.3f}Mops"
               for s, c in sorted(crash.items())))
EOF

echo "== observability sweep (tail forensics, writes BENCH_obs.json) =="
python benchmarks/run.py --quick --only obs
require_bench BENCH_obs.json
python - <<'PYEOF'
import json, math

d = json.load(open("BENCH_obs.json"))
assert d["kind"] == "obs"
ladder = d["ladder"]
res = {r["system"]: r for r in d["results"]}
assert set(ladder) <= set(res) and "sherman" in res, sorted(res)
FRACS = ("nic_queue_frac", "atomic_ser_frac", "lock_wait_frac",
         "service_frac")
for name, r in res.items():
    obs = r["obs"]
    # conservation: exact integer attribution + green span accounting
    # on every rung
    assert obs["attr_residual_ps"] == 0, (name, obs["attr_residual_ps"])
    assert obs["spans_ok"], (name, "span accounting broken")
    assert obs["verbs"] > 0 and obs["ops"] > 0, name
    assert len(obs["tail"]) == d["tail_k"], (name, len(obs["tail"]))
    for a in (obs["attribution"], obs["tail_attribution"]):
        assert all(0 <= a[k] <= 1 for k in FRACS), (name, a)
        assert abs(sum(a[k] for k in FRACS) - 1) < 1e-9, (name, a)
# the HOCL story, quantitative: enabling the hierarchical lock moves
# the p99 tail's attribution out of lock-protocol wait and into
# NIC/data time (queue + service), and NIC queueing itself rises
pre = res["+on-chip"]["obs"]["tail_attribution"]
post = res["+hierarchical"]["obs"]["tail_attribution"]
sherman = res["sherman"]["obs"]["tail_attribution"]
for name, t in (("+hierarchical", post), ("sherman", sherman)):
    assert t["lock_wait_frac"] < 0.8 * pre["lock_wait_frac"], \
        ("HOCL must cut the tail lock share", name, t, pre)
    assert t["nic_queue_frac"] > pre["nic_queue_frac"], \
        ("HOCL must raise the tail NIC-queue share", name, t, pre)
    assert (t["nic_queue_frac"] + t["service_frac"]
            > pre["nic_queue_frac"] + pre["service_frac"]), (name, t, pre)
locks = " ".join(
    "{}: lock={:.2f}".format(
        s, res[s]["obs"]["tail_attribution"]["lock_wait_frac"])
    for s in ladder)
print(f"obs OK: {locks} | sherman tail: "
      f"nic={sherman['nic_queue_frac']:.3f} "
      f"svc={sherman['service_frac']:.3f}")
PYEOF

echo "== open-loop CLI smoke (poisson arrivals) =="
python -m repro.workloads --preset write-intensive --quick \
    --records 4000 --ops 256 --batch 128 --systems sherman \
    --n-clients 8 --arrival poisson --rate 0.5 \
    --json BENCH_ci_open.json

echo "== cluster CLI smoke (2 CS, write-intensive) =="
python -m repro.workloads --preset write-intensive --quick \
    --records 4000 --ops 256 --batch 128 --systems sherman \
    --n-clients 2 --json BENCH_ci_cluster.json

echo "== docstring cross-references =="
python scripts/check_xrefs.py

echo "== workload CLI smoke (YCSB-A, tiny) =="
python -m repro.workloads --preset ycsb-a --quick \
    --records 4000 --ops 512 --batch 256 --json BENCH_ci_smoke.json

echo "== cache-enabled workload smoke (YCSB-C, explicit --cache-bytes) =="
python -m repro.workloads --preset ycsb-c --quick \
    --records 4000 --ops 512 --batch 256 --systems sherman \
    --cache-bytes $((64 << 20)) --json BENCH_ci_cache.json

echo "== BENCH json schema validation (docs/BENCHMARKS.md) =="
python - <<'EOF'
import json, math

SPEC_FIELDS = {"name", "read", "insert", "update", "delete", "scan", "rmw",
               "distribution", "theta", "scan_len", "load_records", "ops",
               "batch", "arrival", "offered_mops", "burst_factor",
               "burst_frac", "diurnal_period_s", "diurnal_peak"}
RESULT_FIELDS = {"mops", "p50_us", "p90_us", "p99_us", "counters", "system",
                 "workload", "n_ops", "read_p50_us", "read_p99_us",
                 "write_p50_us", "write_p99_us", "doorbells_p50",
                 "doorbells_p99",
                 "write_bytes_median", "op_counts", "cache_hits",
                 "cache_misses", "cache_stale", "cache_hit_rate",
                 "reads_per_lookup", "verbs", "doorbells",
                 "doorbells_saved", "retried_ops", "n_clients", "rounds",
                 "per_cs", "conservation_ok", "arrival", "offered_mops",
                 "queue_mean_us", "queue_p50_us", "queue_p99_us",
                 "service_mean_us", "slo_us", "slo_attainment",
                 "sustained_frac", "obs"}
COUNTER_KEYS = {"phases", "write_ops", "retried_ops", "read_ops",
                "leaf_splits",
                "internal_splits", "root_splits", "split_same_ms",
                "cas_msgs", "handovers", "msgs", "bytes", "sim_time_s",
                "cache_hits", "cache_misses", "cache_stale", "lookup_ops",
                "lookup_reads", "verbs", "doorbells", "hocl_cas",
                "flat_cas"}
FINITE = ("mops", "p50_us", "p90_us", "p99_us", "doorbells_p50",
          "doorbells_p99", "write_bytes_median")

for path in ("BENCH_ci_smoke.json", "BENCH_ci_cache.json",
             "BENCH_ci_cluster.json", "BENCH_scaling.json",
             "BENCH_ci_open.json", "BENCH_load.json"):
    d = json.load(open(path))
    missing = SPEC_FIELDS - set(d["spec"])
    assert not missing, (path, "spec missing", missing)
    for r in d["results"]:
        assert RESULT_FIELDS <= set(r), (path, RESULT_FIELDS - set(r))
        assert COUNTER_KEYS <= set(r["counters"]), \
            (path, COUNTER_KEYS - set(r["counters"]))
        assert r["mops"] > 0 and r["p99_us"] > 0
        # json floats must be finite: a zero-time run reports 0.0, never
        # the non-standard Infinity token
        for k in FINITE:
            assert math.isfinite(r[k]), (path, k, r[k])

d = json.load(open("BENCH_ci_smoke.json"))
systems = {r["system"] for r in d["results"]}
assert systems == {"sherman", "fg+"}, systems

c = json.load(open("BENCH_ci_cache.json"))["results"][0]
assert c["cache_hit_rate"] >= 0.9, c["cache_hit_rate"]
assert 0 < c["reads_per_lookup"] <= 1.5, c["reads_per_lookup"]

cl = json.load(open("BENCH_ci_cluster.json"))["results"][0]
assert cl["n_clients"] == 2 and len(cl["per_cs"]) == 2, \
    (cl["n_clients"], len(cl["per_cs"]))
assert cl["conservation_ok"] and cl["rounds"] > 0

op = json.load(open("BENCH_ci_open.json"))["results"][0]
assert op["arrival"] == "poisson" and op["offered_mops"] > 0
assert op["queue_mean_us"] >= 0 and op["service_mean_us"] > 0, \
    (op["queue_mean_us"], op["service_mean_us"])
assert 0 < op["sustained_frac"] <= 1
print("BENCH schema OK; cache smoke:",
      f"hit_rate={c['cache_hit_rate']:.3f}",
      f"reads/lookup={c['reads_per_lookup']:.2f};",
      f"cluster smoke: {len(cl['per_cs'])} CS, {cl['rounds']} rounds")
EOF

echo "== bench regression vs committed baselines =="
python scripts/check_bench_regression.py
