#!/usr/bin/env bash
# CI smoke: tier-1 tests + a quick paper-figure run + the workload CLI.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== benchmark smoke (Table 1, quick) =="
python benchmarks/run.py --quick --only table1

echo "== workload CLI smoke (YCSB-A, tiny) =="
python -m repro.workloads --preset ycsb-a --quick \
    --records 4000 --ops 512 --batch 256 --json BENCH_ci_smoke.json
python - <<'EOF'
import json
d = json.load(open("BENCH_ci_smoke.json"))
systems = {r["system"] for r in d["results"]}
assert systems == {"sherman", "fg+"}, systems
assert all(r["mops"] > 0 and r["p99_us"] > 0 for r in d["results"])
print("BENCH_ci_smoke.json OK:",
      {r["system"]: round(r["mops"], 2) for r in d["results"]}, "Mops")
EOF
