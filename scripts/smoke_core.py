"""Quick functional shakeout of the Sherman core (not a pytest)."""
import numpy as np

from repro.core import ShermanIndex, TreeConfig, OracleIndex

rng = np.random.default_rng(0)
cfg = TreeConfig(n_ms=2, nodes_per_ms=512, fanout=8, n_locks_per_ms=1024,
                 max_height=6, n_cs=2)

base_keys = rng.choice(100_000, size=200, replace=False)
base_vals = rng.integers(0, 1_000_000, size=200)
idx = ShermanIndex.build(cfg, base_keys, base_vals)
oracle = OracleIndex()
oracle.insert_batch(base_keys, base_vals)

# lookups of present + absent keys
q = np.concatenate([base_keys[:50], np.array([100_001, 100_002])])
vals, found = idx.lookup(q)
for k, v, f in zip(q, vals, found):
    ov = oracle.lookup(int(k))
    assert (ov is not None) == bool(f), (k, ov, f)
    if ov is not None:
        assert ov == v, (k, ov, v)
print("lookup OK")

# inserts with updates + collisions + splits
for it in range(10):
    ks = rng.integers(0, 100_000, size=64)
    vs = rng.integers(0, 1_000_000, size=64)
    idx.insert(ks, vs)
    oracle.insert_batch(ks, vs)
vals, found = idx.lookup(np.asarray(oracle.items())[:, 0][:500])
items = oracle.items()[:500]
for (k, ov), v, f in zip(items, vals, found):
    assert f and ov == v, (k, ov, v, f)
print("insert OK  splits:", idx.counters["leaf_splits"],
      "internal:", idx.counters["internal_splits"],
      "root:", idx.counters["root_splits"])

# deletes
del_keys = np.asarray([k for k, _ in oracle.items()[:40]])
idx.delete(del_keys)
oracle.delete_batch(del_keys)
vals, found = idx.lookup(del_keys)
assert not found.any(), found.sum()
print("delete OK")

# range
lo = np.asarray([0, 5_000, 50_000], np.int32)
rk, rv, rn = idx.range(lo, count=16)
for i, l in enumerate(lo):
    want = oracle.range(int(l), 16)
    got = [(int(a), int(b)) for a, b in zip(rk[i][:rn[i]], rv[i][:rn[i]])]
    assert got == want, (l, got[:5], want[:5])
print("range OK")

# heavy skew: everyone hits the same keys (contention path)
hot = rng.integers(0, 50, size=256) + 777_000
idx.insert(hot, hot * 2)
for k in np.unique(hot):
    oracle.insert(int(k), int(k) * 2)
# last-lane-wins semantics: value should equal oracle's (same rule)
vals, found = idx.lookup(np.unique(hot))
assert found.all()
assert (vals == np.unique(hot) * 2).all()
print("contention OK  handovers:", idx.counters["handovers"])
print("sim throughput: %.2f Mops, p99 write %.1f us" %
      (idx.throughput_mops(), idx.latency_percentiles()[99]))
print("ALL OK")
