"""Chaos plane: fault injection, crash recovery, differential testing.

Faults are declared on the workload spec (``WorkloadSpec.faults``,
:class:`repro.workloads.spec.FaultEvent`) and executed by
:class:`repro.chaos.runner.ChaosRunner` on the simulated picosecond
timeline; :mod:`repro.chaos.faults` holds the recovery mechanisms and
the differential-harness ground truth; :mod:`repro.chaos.bench` writes
``BENCH_chaos.json`` (DESIGN.md §13).
"""
from repro.chaos.faults import (abandon_repairs, oracle_replay,
                                recovery_trace, requeue_repairs,
                                schedule_for_horizon, tree_contents)
from repro.chaos.runner import ChaosRunner
from repro.chaos.bench import chaos_sweep

__all__ = [
    "ChaosRunner", "abandon_repairs", "chaos_sweep", "oracle_replay",
    "recovery_trace", "requeue_repairs", "schedule_for_horizon",
    "tree_contents",
]
