"""Chaos benchmark: degraded throughput, SLO violations and
time-to-recover under a standard fault schedule (BENCH_chaos.json).

Per system (sherman, fg+):

1. **Calibrate** — a fault-free run of the same workload on a fresh
   cluster gives the horizon ``H`` (total simulated seconds) and the
   fault-free p99, which sets the SLO (``slo_factor``×p99).
2. **Inject** — a second fresh cluster runs the identical op stream
   under :func:`repro.chaos.faults.schedule_for_horizon`: an MS crash
   with full memory loss early, CS leave/join churn mid-run, and a
   hot-key storm (skew shift to a 16-key hotspot) that lifts before the
   end.  Fault times are fractions of the *calibrated* horizon, so both
   systems face faults at comparable run phases.
3. **Audit** — the faulted run must still satisfy the differential
   harness: final tree contents equal the oracle replay of the executed
   write log, conservation across crash boundaries, clean GLT.

The JSON artifact carries one row per (system, fault) with
``ttr_s`` / ``degraded_mops`` / ``slo_violation_frac`` plus the
per-system audit flags — scripts/ci.sh gates on finite recovery for
every fault and a positive degraded throughput for both systems.
"""
from __future__ import annotations

import dataclasses
import json
import tempfile

import numpy as np

from repro.chaos import faults as F
from repro.chaos.runner import ChaosRunner
from repro.cluster.sched import build_cluster
from repro.core.tree import TreeConfig
from repro.workloads.spec import get_preset


#: Chaos runs use a small pool so the quick (CI) configuration still
#: crosses enough rounds to place five faults between round boundaries.
CHAOS_CFG = TreeConfig(n_ms=2, nodes_per_ms=4096, fanout=16,
                       n_locks_per_ms=4096, max_height=6, n_cs=4)


def _build(system: str, records: int, n_clients: int):
    from repro.workloads.engine import SYSTEMS
    return build_cluster(SYSTEMS[system.lower()], CHAOS_CFG,
                         n_clients=n_clients, records=records,
                         cache_bytes=8 << 20, sync_rounds=2)


def chaos_sweep(records: int = 6_000, ops: int = 4_096,
                n_clients: int = 16, preset: str = "write-intensive",
                systems=("sherman", "fg+"), slo_factor: float = 3.0,
                seed: int = 1, out: str = "BENCH_chaos.json") -> dict:
    """Run the calibrate→inject→audit loop and write ``out``."""
    base = get_preset(preset, load_records=records, ops=ops)
    results, schedules = [], {}
    for system in systems:
        # 1. calibrate on a fault-free twin
        cal = ChaosRunner(_build(system, records, n_clients), base,
                          seed=seed).run()
        cal_rep = cal.report()
        horizon = cal_rep["sim_time_s"]
        p99 = [x["p99_us"] for x in cal.samples if x["p99_us"] > 0]
        slo_us = slo_factor * float(np.median(p99)) if p99 else None
        # 2. inject
        sched = F.schedule_for_horizon(horizon, cs=1)
        spec = base.replace(faults=sched)
        schedules[system] = [dataclasses.asdict(ev) for ev in sched]
        with tempfile.TemporaryDirectory() as ckpt:
            runner = ChaosRunner(
                _build(system, records, n_clients), spec, seed=seed,
                ckpt_dir=ckpt, slo_us=slo_us,
                ckpt_every=max(1, cal.total_rounds // 8))
            runner.run()
            rep = runner.report()
        # 3. audit: differential oracle over the executed write log
        oracle = F.oracle_replay(
            *_load_keys_vals(records), runner.write_log)
        got = F.tree_contents(runner.cluster.state)
        oracle_ok = got == dict(oracle.items())
        results.append(dict(
            system=system, slo_us=slo_us,
            horizon_s=float(horizon),
            calibrated_mops=cal_rep["overall_mops"],
            baseline_mops=rep["baseline_mops"],
            overall_mops=rep["overall_mops"],
            oracle_ok=bool(oracle_ok),
            conservation_ok=rep["conservation_ok"],
            glt_clean=rep["glt_clean"],
            unfired_faults=rep["unfired_faults"],
            faults=rep["faults"]))
    payload = dict(kind="chaos", preset=preset, records=records,
                   ops=ops, n_clients=n_clients, seed=seed,
                   spec=base.to_dict(), schedules=schedules,
                   results=results)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return payload


def _load_keys_vals(records: int, keyspace: int = 1 << 20,
                    seed: int = 0):
    """The exact bulk-load records ``build_cluster`` used (same seed)."""
    from repro.cluster.sched import VAL_MASK
    from repro.workloads.keygen import scramble
    rng = np.random.default_rng(seed)
    keys = scramble(np.arange(records, dtype=np.int64), keyspace)
    vals = rng.integers(0, VAL_MASK, size=records)
    return keys, vals
