"""Fault-injection primitives for the chaos plane (DESIGN.md §13).

The declarative side of a fault schedule lives with the workload spec
(:class:`repro.workloads.spec.FaultEvent`, ``WorkloadSpec.faults``); this
module holds the *mechanisms* the runner applies when an event fires:

* **Repair-queue abandonment / re-derivation** — an MS crash breaks the
  wave in flight: half-splits parked in the shared repair queue are
  *abandoned* (host-mirrored, then cleared).  The B-link invariant keeps
  the tree correct meanwhile — a half-split leaf is reachable through
  its sibling pointer — so abandonment is safe; recovery either
  **re-derives** the pending separators from the mirror after a priced
  survey scan of the crashed server's rows (memory survived) or lets the
  redo-log **replay** regenerate and drain them (memory lost).
* **Recovery verb traces** — the priced wire cost of coming back: the
  GLT re-initialization write (on-chip SRAM is re-armed to all-free),
  the survey scan or the checkpoint re-population writes.  Recovery
  traffic is merged onto the shared timeline like any other trace, so
  conservation invariants hold across crash boundaries.
* **Tree-content extraction + oracle replay** — the differential
  harness's ground truth: the final key→value map of a (possibly
  faulted) cluster must equal a :class:`repro.core.ref.OracleIndex`
  replay of the executed write log (tests/test_chaos.py).
"""
from __future__ import annotations

import numpy as np

from repro.core import verbs as V
from repro.core.api import REPAIR_CAP
from repro.core.ref import OracleIndex
from repro.core.tree import EMPTY_KEY, TreeConfig, TreeState
from repro.core.write import RepairQueue
from repro.workloads.spec import FaultEvent

import jax.numpy as jnp

#: Cap on discrete verbs per recovery trace: beyond this the modeled I/O
#: is aggregated into equal-sized chunks (bytes conserved, event count
#: bounded) so a huge restore never explodes the event loop.
MAX_RECOVERY_VERBS = 256


# --------------------------------------------------------------------------
# repair-queue crash handling
# --------------------------------------------------------------------------

def abandon_repairs(cluster):
    """Snapshot-and-clear the cluster's wave-scope repair queue.

    Returns a host-side mirror dict (``sep``/``child``/``level``/
    ``valid`` numpy arrays) when entries were pending, else ``None``.
    The mirror is what a real recovery scan would re-derive from the
    surviving B-link structure — half-splits are self-describing (the
    sibling pointer and fence keys name the missing separator), so the
    simulation keeps the mirror instead of re-walking the tree.
    """
    q = cluster.repair
    valid = np.asarray(q.valid)
    if not cluster._repair_backlog and not valid.any():
        cluster.repair = RepairQueue.empty(REPAIR_CAP)
        cluster._repair_backlog = 0
        return None
    mirror = dict(sep=np.asarray(q.sep).copy(),
                  child=np.asarray(q.child).copy(),
                  level=np.asarray(q.level).copy(),
                  valid=valid.copy())
    cluster.repair = RepairQueue.empty(REPAIR_CAP)
    cluster._repair_backlog = 0
    return mirror


def requeue_repairs(cluster, mirror: dict) -> int:
    """Re-derive: push a mirror taken by :func:`abandon_repairs` back
    into the (empty) queue and return the pending count."""
    cluster.repair = RepairQueue(
        sep=jnp.asarray(mirror["sep"]), child=jnp.asarray(mirror["child"]),
        level=jnp.asarray(mirror["level"]),
        valid=jnp.asarray(mirror["valid"]))
    n = int(mirror["valid"].sum())
    cluster._repair_backlog = n
    return n


# --------------------------------------------------------------------------
# recovery verb traces
# --------------------------------------------------------------------------

def _chunks(total_bytes: int, max_verbs: int) -> np.ndarray:
    """Split a byte total into <= max_verbs near-equal chunks (>=1 each)."""
    total_bytes = int(total_bytes)
    if total_bytes <= 0:
        return np.zeros(0, np.int64)
    n = int(min(max_verbs, total_bytes))
    base = total_bytes // n
    out = np.full(n, base, np.int64)
    out[:total_bytes - base * n] += 1
    return out


def recovery_trace(cfg: TreeConfig, ms: int, *, scan_rows: int = 0,
                   restore_rows: int = 0, small_bytes: int = 64,
                   max_verbs: int = MAX_RECOVERY_VERBS) -> V.VerbTrace:
    """The restart protocol's wire cost, as one background verb trace.

    Always: one GLT re-initialization WRITE (the whole on-chip lock
    array is re-armed to free — ``n_locks_per_ms * 2`` bytes, §4.3's
    16-bit lock words).  Plus either

    * ``scan_rows`` small survey READs of the crashed server's allocated
      rows (memory survived: re-derive which half-splits were pending),
      or
    * ``restore_rows`` whole-node WRITEs re-populating the crashed
      server's share of the pool from the last checkpoint (memory lost;
      the checkpoint store itself is off-path, so only the writes back
      into the MS are priced — the redo replay is priced separately by
      the real write waves it re-runs).

    All verbs are background (lane -1), independent (own doorbells), and
    target the restarted ``ms`` whose NIC starts empty — so the trace's
    makespan is the server's genuine restart I/O time.
    """
    glt_bytes = np.array([cfg.n_locks_per_ms * 2], np.int64)
    scan = _chunks(int(scan_rows) * small_bytes, max_verbs)
    rest = _chunks(int(restore_rows) * cfg.node_bytes, max_verbs)
    nbytes = np.concatenate([glt_bytes, scan, rest])
    kind = np.concatenate([
        np.full(1, V.WRITE, np.int8),
        np.full(scan.size, V.READ, np.int8),
        np.full(rest.size, V.WRITE, np.int8)])
    role = np.concatenate([
        np.full(1, V.UNLOCK, np.int8),          # lock-plane re-arm
        np.full(scan.size, V.SYNC, np.int8),    # survey reads
        np.full(rest.size, V.MAINT, np.int8)])  # image re-population
    n = nbytes.size
    return V.VerbTrace(
        kind=kind, role=role,
        ms=np.full(n, int(ms), np.int32), nbytes=nbytes,
        lane=np.full(n, -1, np.int32),
        doorbell=np.arange(n, dtype=np.int64),
        dep=np.full(n, -1, np.int64), dep2=np.full(n, -1, np.int64),
        at=np.zeros(n), n_lanes=0, meta={})


# --------------------------------------------------------------------------
# differential-harness ground truth
# --------------------------------------------------------------------------

def tree_contents(state: TreeState) -> dict:
    """The live key→value map of a tree — leaf entries of non-free
    level-0 nodes.  This is the quantity every faulted run must agree
    with the fault-free oracle on (tests/test_chaos.py)."""
    level = np.asarray(state.level)
    free = np.asarray(state.free_bit)
    leaf = (level == 0) & ~free
    keys = np.asarray(state.keys)[leaf].ravel()
    vals = np.asarray(state.vals)[leaf].ravel()
    m = keys != EMPTY_KEY
    return dict(zip(keys[m].tolist(), vals[m].tolist()))


def oracle_replay(base_keys, base_vals, write_log) -> OracleIndex:
    """Build the fault-free oracle: bulk-loaded records plus the
    *executed* write log replayed in lane order.

    ``write_log`` entries are ``(keys_by_slot, vals_by_slot, is_delete)``
    exactly as the waves executed them (after any CS-leave failover
    reassignment), so last-writer-wins resolves identically to the
    stacked dispatch's intra-batch dedupe."""
    oracle = OracleIndex()
    oracle.insert_batch(np.asarray(base_keys), np.asarray(base_vals))
    for keys_by, vals_by, is_del in write_log:
        for slot, k in enumerate(keys_by):
            if k is None or len(k) == 0:
                continue
            if is_del:
                oracle.delete_batch(k)
            else:
                v = None if vals_by is None else vals_by[slot]
                if v is None:
                    v = np.zeros(len(k), np.int32)
                oracle.insert_batch(k, v)
    return oracle


def schedule_for_horizon(horizon_s: float, *, ms: int = 0, cs: int = 1,
                         down_frac: float = 0.04,
                         lose_memory: bool = True,
                         storm_theta: float = 0.99) -> tuple:
    """A standard all-three-kinds schedule placed at fixed fractions of
    a (calibrated) run horizon: MS crash early, CS churn mid-run, a
    hot-key storm late that lifts before the end so time-to-recover is
    measurable for every fault.  Used by the chaos benchmark and tests.
    """
    h = float(horizon_s)
    return (
        FaultEvent("ms_crash", at_s=0.20 * h, ms=ms,
                   down_s=down_frac * h, lose_memory=lose_memory),
        FaultEvent("cs_leave", at_s=0.42 * h, cs=cs),
        FaultEvent("cs_join", at_s=0.58 * h, cs=cs),
        FaultEvent("skew_shift", at_s=0.72 * h, distribution="hotspot",
                   theta=storm_theta, hot_frac=0.95, hot_n=16),
        FaultEvent("skew_shift", at_s=0.86 * h, distribution="zipfian",
                   theta=storm_theta),
    )
