"""ChaosRunner — drive a cluster workload through a fault schedule.

A deterministic re-implementation of :func:`repro.cluster.sched.run_cluster`
(same per-round draw order, same RNG consumption) with three extra powers:

* **Fault injection** on the simulated clock: events from
  ``WorkloadSpec.faults`` fire when ``sim_time_s`` passes their ``at_s``.
  MS crashes land *mid-wave* — the next write wave runs with
  ``drain=False`` so its half-splits are stranded in the repair queue
  when the server dies; CS leave/join and skew shifts apply at round
  boundaries (they are control-plane events).
* **Crash recovery**: abandon + re-derive the repair queue, GLT
  re-initialization, optional full memory loss (restore the tree image
  from the last checkpoint and replay the redo log of executed write
  waves), all priced as recovery traffic on the shared timeline.
* **Snapshot / resume**: a periodic full-run checkpoint (tree + repair
  queue + per-CS cache images as array leaves; RNG states, counters,
  cursors as a JSON side record) from which a *fresh* runner resumes
  tick-for-tick identical — equal merged-trace digests — to the
  uninterrupted run (tests/test_chaos.py).

Determinism contract: every CS draws from its stream every round even
while dead (a dead CS's clients fail over, they do not stop arriving),
so the op stream is identical across fault schedules; only *placement*
changes.  The executed write log (post-failover) is the ground truth the
differential oracle replays.

Replayed redo waves re-price the lost work (honest: the work is done
twice) but their latency/doorbell samples are excised — replay is not
client traffic.  Checkpoint writes themselves are not priced: the model
is an incremental, off-path checkpoint stream (DESIGN.md §13).
"""
from __future__ import annotations

import os
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.chaos import faults as F
from repro.checkpoint.manager import CheckpointManager
from repro.cluster.sched import VAL_MASK, Cluster
from repro.cluster.streams import ClusterStreams
from repro.core import hocl
from repro.core.tree import TreeState
from repro.core.write import RepairQueue
from repro.workloads.spec import OP_KINDS, WorkloadSpec


class ChaosRunner:
    """One workload run over a :class:`Cluster`, with faults."""

    def __init__(self, cluster: Cluster, spec: WorkloadSpec, *,
                 seed: int = 1, keyspace: int = 1 << 20,
                 partitioned: bool = False,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 keep: int = 4, slo_us: Optional[float] = None):
        self.cluster = cluster
        self.spec = spec
        self.keyspace = int(keyspace)
        self.schedule = sorted(spec.faults, key=lambda e: e.at_s)
        self.streams = ClusterStreams(spec, cluster.n_cs,
                                      keyspace=keyspace,
                                      partitioned=partitioned, seed=seed)
        self.mgr = (CheckpointManager(ckpt_dir, keep=keep)
                    if ckpt_dir else None)
        self.ckpt_every = int(ckpt_every)
        self.slo_us = slo_us
        self.alive = [True] * cluster.n_cs
        self.round_no = 0
        self.done = 0
        self.op_counts = {k: 0 for k in OP_KINDS}
        self.samples: list[dict] = []      # per-round timing/ops/SLO rows
        self.fault_log: list[dict] = []    # fired events, with effects
        self.write_log: list[tuple] = []   # executed write waves (oracle)
        self._redo: list[tuple] = []       # since last checkpoint (replay)
        self._fault_i = 0
        self._pending_crash: list = []
        self._replaying = False

    # -- geometry ----------------------------------------------------------
    @property
    def total_rounds(self) -> int:
        per_round = self.cluster.per_cs * self.cluster.n_cs
        return max(1, -(-self.spec.ops // per_round))

    # -- fault firing ------------------------------------------------------
    def _log_fault(self, entry: dict) -> None:
        """Append to the fault log and, when the cluster carries an
        observability recorder, mark the event as an instant marker on
        the recorded timeline (repro.obs)."""
        self.fault_log.append(entry)
        rec = self.cluster.recorder
        if rec is not None:
            rec.mark_fault(entry["kind"], entry["t_fault_s"],
                           **{k: v for k, v in entry.items()
                              if k not in ("kind", "t_fault_s")})

    def _fire_due(self) -> None:
        now = self.cluster.counters["sim_time_s"]
        while (self._fault_i < len(self.schedule)
               and self.schedule[self._fault_i].at_s <= now):
            ev = self.schedule[self._fault_i]
            self._fault_i += 1
            getattr(self, "_on_" + ev.kind)(ev, now)

    def _on_ms_crash(self, ev, now: float) -> None:
        # the crash lands inside the round's next write wave (drain=False
        # strands its half-splits); _write applies the actual effects.
        self._pending_crash.append(ev)

    def _on_cs_leave(self, ev, now: float) -> None:
        cs = int(ev.cs)
        if not self.alive[cs] or sum(self.alive) <= 1:
            self._log_fault(dict(kind="cs_leave", cs=cs,
                                 t_fault_s=now, skipped=True))
            return
        self.alive[cs] = False
        self._log_fault(dict(kind="cs_leave", cs=cs, t_fault_s=now))

    def _on_cs_join(self, ev, now: float) -> None:
        cs = int(ev.cs)
        if self.alive[cs]:
            self._log_fault(dict(kind="cs_join", cs=cs,
                                 t_fault_s=now, skipped=True))
            return
        self.alive[cs] = True
        # cold restart: the joining CS's private image is gone — its
        # first reads trigger full fills (the priced warm-up transient)
        self.cluster.nodes[cs].cache.reset()
        self._log_fault(dict(kind="cs_join", cs=cs, t_fault_s=now))

    def _on_skew_shift(self, ev, now: float) -> None:
        kw = {}
        if ev.distribution:
            kw["distribution"] = ev.distribution
        if ev.theta >= 0:
            kw["theta"] = ev.theta
        if ev.hot_frac >= 0:
            kw["hot_frac"] = ev.hot_frac
        if ev.hot_n >= 1:
            kw["hot_n"] = ev.hot_n
        self.streams.shift_skew(**kw)
        self._log_fault(dict(kind="skew_shift", t_fault_s=now, **{
            k: (float(v) if isinstance(v, float) else v)
            for k, v in kw.items()}))

    # -- crash recovery ----------------------------------------------------
    def _apply_crash(self, ev) -> None:
        cl = self.cluster
        t0 = cl.counters["sim_time_s"]
        # 1. the on-chip state is gone: strand the repair queue on a host
        #    mirror, zero the crashed server's GLT rows
        mirror = F.abandon_repairs(cl)
        abandoned = int(mirror["valid"].sum()) if mirror else 0
        cl.state = hocl.reset_glt(cl.state, ev.ms)
        # 2. downtime: the pool is a single symmetric fabric, so a dead
        #    MS stalls the fleet until restart (no per-MS routing around
        #    the failure in this model)
        restart = t0 + float(ev.down_s)
        cl.counters["sim_time_s"] = restart
        if cl.clock is not None:
            # the NIC's queued-but-unissued verbs died with the server
            cl.clock.reset_ms(int(ev.ms), restart)
        rows_ms = int(np.asarray(cl.state.alloc_next)[int(ev.ms)])
        marks = (len(cl.latencies_write), len(cl.doorbells_write),
                 len(cl.write_bytes), len(cl.queue_write))
        replayed = 0
        if ev.lose_memory:
            if self.mgr is None or not self.mgr.steps():
                raise RuntimeError(
                    "ms_crash with lose_memory needs a checkpoint "
                    "(pass ckpt_dir to ChaosRunner)")
            cl.state = self._restore_tree_latest()
            # redo replay: re-run every write wave executed since the
            # checkpoint (deterministic — same batches, same state), the
            # stranded half-splits included in the last entry's drain
            self._replaying = True
            try:
                for kb, vb, isd in self._redo:
                    cl.write_wave(kb, vb, is_delete=isd)
            finally:
                self._replaying = False
            replayed = len(self._redo)
        elif mirror is not None:
            # memory survived: re-derive the stranded separators from
            # the surviving B-link structure and complete them
            F.requeue_repairs(cl, mirror)
            cl.drain_repairs()
        # replayed work is not client traffic: drop its samples
        del cl.latencies_write[marks[0]:]
        del cl.doorbells_write[marks[1]:]
        del cl.write_bytes[marks[2]:]
        del cl.queue_write[marks[3]:]
        # 3. price the restart protocol itself (GLT re-arm + survey scan
        #    or image re-population), attributed to the first alive CS
        rec_cs = self.alive.index(True)
        trace = F.recovery_trace(
            cl.cfg, int(ev.ms),
            scan_rows=0 if ev.lose_memory else rows_ms,
            restore_rows=rows_ms if ev.lose_memory else 0,
            small_bytes=cl.net.small_io_bytes)
        cl._simulate_merged([(rec_cs, trace)], "maint")
        self._log_fault(dict(
            kind="ms_crash", ms=int(ev.ms), t_fault_s=float(t0),
            t_restart_s=float(restart), down_s=float(ev.down_s),
            lose_memory=bool(ev.lose_memory),
            abandoned_repairs=abandoned, replayed_waves=replayed))

    # -- failover placement ------------------------------------------------
    def _reassign(self, arrs: list, companions: Optional[list] = None):
        """Move dead slots' batches onto alive CSs (deterministic
        round-robin by dead-slot id).  ``companions`` (values drawn for
        the same keys) moves in lockstep so key/value pairing survives
        failover."""
        if all(self.alive):
            return (arrs, companions) if companions is not None else arrs
        alive_ids = [i for i, a in enumerate(self.alive) if a]
        out = list(arrs)
        comp = list(companions) if companions is not None else None

        def fold(lst, dst, src):
            lst[dst] = (lst[src] if lst[dst] is None
                        else np.concatenate([lst[dst], lst[src]]))
            lst[src] = None
        for d, a in enumerate(self.alive):
            if a or out[d] is None:
                continue
            p = alive_ids[d % len(alive_ids)]
            fold(out, p, d)
            if comp is not None:
                fold(comp, p, d)
        return (out, comp) if comp is not None else out

    # -- the write path ----------------------------------------------------
    def _write(self, keys_by, vals_by=None, is_delete: bool = False):
        crash = bool(self._pending_crash)
        self.cluster.write_wave(keys_by, vals_by, is_delete=is_delete,
                                drain=not crash)
        entry = (keys_by, vals_by, is_delete)
        self.write_log.append(entry)
        self._redo.append(entry)
        if crash:
            while self._pending_crash:
                self._apply_crash(self._pending_crash.pop(0))

    # -- one round (mirrors run_cluster's draw order exactly) --------------
    def _run_round(self, r: int) -> None:
        self._fire_due()
        cl, streams = self.cluster, self.streams
        n_cs, per_cs = cl.n_cs, cl.per_cs
        t0 = cl.counters["sim_time_s"]
        mw, mr = len(cl.latencies_write), len(cl.latencies_read)
        counts = [self.spec.batch_counts(per_cs, salt=r * n_cs + cs)
                  for cs in range(n_cs)]

        def gather(kind, draw):
            return [draw(cs, counts[cs][kind]) if counts[cs][kind] else None
                    for cs in range(n_cs)]

        if any(c["scan"] for c in counts):
            cl.scan_wave(self._reassign(gather("scan", streams.draw)),
                         count=self.spec.scan_len,
                         max_leaves=max(4, self.spec.scan_len))
        if any(c["read"] for c in counts):
            cl.lookup_wave(self._reassign(gather("read", streams.draw)))
        if any(c["rmw"] for c in counts):
            keys = self._reassign(gather("rmw", streams.draw))
            got = cl.lookup_wave(keys)
            vals = [((g.astype(np.int64) + 1) & VAL_MASK)
                    if k is not None else None
                    for k, (g, _) in zip(keys, got)]
            self._write(keys, vals)
        if any(c["update"] for c in counts):
            keys = gather("update", streams.draw)
            vals = [streams.rngs[cs].integers(0, VAL_MASK, k.size)
                    if k is not None else None
                    for cs, k in enumerate(keys)]
            self._write(*self._reassign(keys, vals))
        if any(c["delete"] for c in counts):
            self._write(self._reassign(gather("delete", streams.draw)),
                        None, is_delete=True)
        if any(c["insert"] for c in counts):
            keys = gather("insert", streams.draw_insert)
            vals = [streams.rngs[cs].integers(0, VAL_MASK, k.size)
                    if k is not None else None
                    for cs, k in enumerate(keys)]
            self._write(*self._reassign(keys, vals))
        cl.end_round()
        while self._pending_crash:      # crash in a write-less round
            self._apply_crash(self._pending_crash.pop(0))
        # per-round sample: the recovery-time / degraded-throughput basis
        t1 = cl.counters["sim_time_s"]
        new = cl.latencies_write[mw:] + cl.latencies_read[mr:]
        lat = (np.concatenate(new) if new else np.zeros(0))
        ops = sum(sum(c.values()) for c in counts)
        viol = (int((lat * 1e6 > self.slo_us).sum())
                if self.slo_us else 0)
        self.samples.append(dict(
            r=r, t0=float(t0), t1=float(t1), ops=int(ops),
            n_lat=int(lat.size), slo_viol=viol,
            p99_us=(float(np.quantile(lat, 0.99) * 1e6)
                    if lat.size else 0.0)))
        self.done += ops
        for c in counts:
            for k in OP_KINDS:
                self.op_counts[k] += c[k]

    # -- driving -----------------------------------------------------------
    def run(self, until_round: Optional[int] = None) -> "ChaosRunner":
        stop = self.total_rounds
        if until_round is not None:
            stop = min(stop, int(until_round))
        if (self.mgr is not None and self.round_no == 0
                and not self.mgr.steps()):
            self.save_checkpoint()      # a lose_memory crash at any time
        while self.round_no < stop:     # has something to restore
            self._run_round(self.round_no)
            self.round_no += 1
            if (self.mgr is not None and self.ckpt_every
                    and self.round_no % self.ckpt_every == 0):
                self.save_checkpoint()
        return self

    # -- snapshot / resume -------------------------------------------------
    _IMG_SENTINEL = "__no_image__"

    def save_checkpoint(self) -> None:
        """Full-run snapshot at a round boundary: array leaves through the
        :class:`CheckpointManager` (validated on restore), host scalars as
        the JSON side record.  Doubles as the crash-recovery checkpoint:
        the redo log resets here, in the original and the resumed run
        alike, so later crashes replay the same waves either way."""
        cl = self.cluster
        arrays: dict[str, np.ndarray] = {}
        for f, v in zip(TreeState._fields, cl.state):
            arrays[f"state/{f}"] = np.asarray(v)
        for f, v in zip(RepairQueue._fields, cl.repair):
            arrays[f"repair/{f}"] = np.asarray(v)
        cache_scalars, cache_img_keys = [], []
        for i, node in enumerate(cl.nodes):
            img, sc = node.cache.export_state()
            cache_scalars.append(sc)
            cache_img_keys.append(sorted(img) if img else None)
            if img:
                for k, v in img.items():
                    arrays[f"cache{i}/{k}"] = v
        extra = dict(
            array_keys=sorted(arrays),
            round_no=self.round_no, done=self.done,
            op_counts=self.op_counts,
            counters=cl.counters, repair_backlog=cl._repair_backlog,
            node_counters=[dict(n.counters) for n in cl.nodes],
            cache_scalars=cache_scalars, cache_img_keys=cache_img_keys,
            streams=self.streams.export_state(),
            alive=list(self.alive), fault_i=self._fault_i,
            fault_log=self.fault_log, samples=self.samples,
            n_digests=(len(cl.trace_log)
                       if cl.trace_log is not None else 0),
        )
        self.mgr.save(arrays, step=self.round_no, extra=extra)
        self._redo = []

    def _raw_by_key(self, step: int) -> tuple[dict, dict]:
        extra = self.mgr.restore_extra(step)
        raw = self.mgr.restore_raw(step)
        # save() flattened a dict: leaves are ordered by sorted key
        vals = [raw[n] for n in sorted(raw)]
        return dict(zip(extra["array_keys"], vals)), extra

    def _restore_tree_latest(self) -> TreeState:
        by_key, _ = self._raw_by_key(self.mgr.steps()[-1])
        return TreeState(*[jnp.asarray(by_key[f"state/{f}"])
                           for f in TreeState._fields])

    def load_latest(self) -> int:
        """Resume a fresh runner (same build recipe) from the newest
        snapshot; returns the round to continue from."""
        step = self.mgr.steps()[-1]
        by_key, extra = self._raw_by_key(step)
        cl = self.cluster
        cl.state = TreeState(*[jnp.asarray(by_key[f"state/{f}"])
                               for f in TreeState._fields])
        cl.repair = RepairQueue(*[jnp.asarray(by_key[f"repair/{f}"])
                                  for f in RepairQueue._fields])
        cl._repair_backlog = int(extra["repair_backlog"])
        cl.counters = dict(extra["counters"])
        for i, node in enumerate(cl.nodes):
            keys = extra["cache_img_keys"][i]
            img = ({k: by_key[f"cache{i}/{k}"] for k in keys}
                   if keys else None)
            node.cache.import_state(img, extra["cache_scalars"][i])
            node.counters = dict(extra["node_counters"][i])
        self.streams.import_state(extra["streams"])
        self.alive = [bool(a) for a in extra["alive"]]
        self._fault_i = int(extra["fault_i"])
        self.fault_log = list(extra["fault_log"])
        self.samples = list(extra["samples"])
        self.round_no = int(extra["round_no"])
        self.done = int(extra["done"])
        self.op_counts = {k: int(v)
                          for k, v in extra["op_counts"].items()}
        self._redo = []
        return self.round_no

    # -- reporting ---------------------------------------------------------
    def report(self, recover_frac: float = 0.7,
               recover_rounds: int = 2) -> dict:
        """Recovery metrics per fired fault.

        Baseline = median per-round throughput before the first fault.
        A fault has *recovered* at the end of the first round that opens
        a run of ``recover_rounds`` consecutive rounds at or above
        ``recover_frac``×baseline; TTR and the degraded-window
        throughput/SLO-violation fraction follow from that point.
        """
        s, cl = self.samples, self.cluster
        tput = [x["ops"] / (x["t1"] - x["t0"]) if x["t1"] > x["t0"]
                else 0.0 for x in s]
        fired = [f for f in self.fault_log if not f.get("skipped")]
        first_t = min((f["t_fault_s"] for f in fired), default=None)
        pre = [tp for x, tp in zip(s, tput)
               if first_t is None or x["t1"] <= first_t]
        baseline = float(np.median(pre if pre else tput)) if s else 0.0
        rows = []
        for f in fired:
            tf = f["t_fault_s"]
            t_rec = None
            for j, x in enumerate(s):
                if x["t1"] <= tf:
                    continue
                win = tput[j:j + recover_rounds]
                if (len(win) == recover_rounds and baseline > 0
                        and all(w >= recover_frac * baseline
                                for w in win)):
                    t_rec = s[j]["t1"]
                    break
            row = dict(f)
            if t_rec is not None and t_rec > tf:
                win = [x for x in s if tf < x["t1"] <= t_rec]
                n_ops = sum(x["ops"] for x in win)
                n_lat = sum(x["n_lat"] for x in win)
                row.update(
                    t_recover_s=float(t_rec), ttr_s=float(t_rec - tf),
                    degraded_mops=n_ops / (t_rec - tf) / 1e6,
                    slo_violation_frac=(
                        sum(x["slo_viol"] for x in win) / n_lat
                        if n_lat else 0.0))
            else:
                row.update(t_recover_s=None, ttr_s=None,
                           degraded_mops=None, slo_violation_frac=None)
            rows.append(row)
        return dict(
            baseline_mops=baseline / 1e6,
            overall_mops=cl.throughput_mops(),
            done=self.done, rounds=self.round_no,
            sim_time_s=float(cl.counters["sim_time_s"]),
            conservation_ok=bool(cl.conservation_ok()),
            glt_clean=bool((np.asarray(cl.state.glt) == 0).all()),
            unfired_faults=len(self.schedule) - self._fault_i
            + len(self._pending_crash),
            faults=rows)
