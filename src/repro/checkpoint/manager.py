"""Checkpointing: atomic, manifest-based, keep-last-k, resumable.

Every leaf is saved as a raw ``.npy`` with a JSON manifest describing the
pytree structure; the step directory is written to a temp name and renamed
(atomic on POSIX) so a crash mid-save never corrupts the latest checkpoint.
On a real cluster this sits behind Orbax/tensorstore with per-shard writes;
the manager's interface (save / restore_latest / gc) is the same.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _flatten_with_names(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    names = [f"leaf_{i:05d}" for i in range(len(leaves))]
    return names, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save -------------------------------------------------------------
    def save(self, tree: Any, step: int) -> str:
        names, leaves, _ = _flatten_with_names(tree)
        tmp = os.path.join(self.dir, f".tmp_step_{step:08d}")
        final = os.path.join(self.dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        for name, leaf in zip(names, leaves):
            arr = np.asarray(jax.device_get(leaf))
            np.save(os.path.join(tmp, name + ".npy"), arr)
            manifest["leaves"][name] = {"dtype": str(arr.dtype),
                                        "shape": list(arr.shape)}
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)            # atomic publish
        self.gc()
        return final

    # -- restore ----------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, _MANIFEST)):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def restore(self, template: Any, step: int):
        path = os.path.join(self.dir, f"step_{step:08d}")
        names, leaves, treedef = _flatten_with_names(template)
        loaded = []
        for name, leaf in zip(names, leaves):
            arr = np.load(os.path.join(path, name + ".npy"))
            want = tuple(np.shape(leaf))
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"checkpoint leaf {name} shape {arr.shape} != {want}")
            loaded.append(arr)
        return jax.tree_util.tree_unflatten(treedef, loaded)

    def restore_latest(self, template: Any
                       ) -> Optional[tuple[Any, int]]:
        steps = self.steps()
        if not steps:
            return None
        s = steps[-1]
        return self.restore(template, s), s

    # -- retention --------------------------------------------------------
    def gc(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
