"""Checkpointing: atomic, manifest-based, keep-last-k, resumable.

Every leaf is saved as a raw ``.npy`` with a JSON manifest describing the
pytree structure; the step directory is written to a temp name and renamed
(atomic on POSIX) so a crash mid-save never corrupts the latest checkpoint.
On restore every leaf is validated against the manifest's recorded dtype
and shape *before* it is accepted — a truncated, stale, or foreign ``.npy``
fails loudly instead of loading silently (the chaos plane's recovery path
depends on this; see tests/test_checkpoint_train.py's corruption-injection
cases).  On a real cluster this sits behind Orbax/tensorstore with
per-shard writes; the manager's interface (save / restore_latest / gc) is
the same.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np

_MANIFEST = "manifest.json"
_EXTRA = "extra.json"


def _flatten_with_names(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    names = [f"leaf_{i:05d}" for i in range(len(leaves))]
    return names, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save -------------------------------------------------------------
    def save(self, tree: Any, step: int,
             extra: Optional[dict] = None) -> str:
        """Atomically publish ``tree``'s leaves plus an optional
        JSON-serializable ``extra`` side record (host-side scalars — RNG
        states, counters — that ride along with the array leaves)."""
        names, leaves, _ = _flatten_with_names(tree)
        tmp = os.path.join(self.dir, f".tmp_step_{step:08d}")
        final = os.path.join(self.dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        for name, leaf in zip(names, leaves):
            arr = np.asarray(jax.device_get(leaf))
            np.save(os.path.join(tmp, name + ".npy"), arr)
            manifest["leaves"][name] = {"dtype": str(arr.dtype),
                                        "shape": list(arr.shape)}
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        if extra is not None:
            with open(os.path.join(tmp, _EXTRA), "w") as f:
                json.dump(extra, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)            # atomic publish
        self.gc()
        return final

    # -- restore ----------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, _MANIFEST)):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def _manifest(self, step: int) -> dict:
        path = os.path.join(self.dir, f"step_{step:08d}", _MANIFEST)
        with open(path) as f:
            return json.load(f)

    def _load_leaf(self, step: int, name: str, entry: dict) -> np.ndarray:
        """Load one ``.npy`` and validate it against its manifest entry.

        The manifest is the ground truth written at save time; a leaf
        whose on-disk dtype/shape disagrees (truncated write, stale file
        from an older run, bit-rot) must never be accepted silently.
        """
        path = os.path.join(self.dir, f"step_{step:08d}", name + ".npy")
        try:
            arr = np.load(path)
        except Exception as e:            # truncated/corrupt npy header
            raise ValueError(
                f"checkpoint leaf {name} at step {step} is unreadable "
                f"({e})") from e
        if str(arr.dtype) != entry["dtype"]:
            raise ValueError(
                f"checkpoint leaf {name} dtype {arr.dtype} != manifest "
                f"{entry['dtype']} (stale or corrupt leaf)")
        if list(arr.shape) != list(entry["shape"]):
            raise ValueError(
                f"checkpoint leaf {name} shape {list(arr.shape)} != "
                f"manifest {entry['shape']} (stale or corrupt leaf)")
        return arr

    def restore(self, template: Any, step: int):
        manifest = self._manifest(step)
        names, leaves, treedef = _flatten_with_names(template)
        if set(names) != set(manifest["leaves"]):
            raise ValueError(
                f"checkpoint step {step} has {len(manifest['leaves'])} "
                f"leaves, template has {len(names)}")
        loaded = []
        for name, leaf in zip(names, leaves):
            arr = self._load_leaf(step, name, manifest["leaves"][name])
            want = tuple(np.shape(leaf))
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"checkpoint leaf {name} shape {arr.shape} != {want}")
            loaded.append(arr)
        return jax.tree_util.tree_unflatten(treedef, loaded)

    def restore_raw(self, step: int) -> dict[str, np.ndarray]:
        """Load every leaf of a step by manifest name (validated), without
        needing a structural template — callers that saved a flat dict
        reassemble it themselves (the chaos plane's run snapshots)."""
        manifest = self._manifest(step)
        return {name: self._load_leaf(step, name, entry)
                for name, entry in sorted(manifest["leaves"].items())}

    def restore_extra(self, step: int) -> Optional[dict]:
        """The JSON side record saved alongside ``step`` (None if absent)."""
        path = os.path.join(self.dir, f"step_{step:08d}", _EXTRA)
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    def restore_latest(self, template: Any
                       ) -> Optional[tuple[Any, int]]:
        steps = self.steps()
        if not steps:
            return None
        s = steps[-1]
        return self.restore(template, s), s

    # -- retention --------------------------------------------------------
    def gc(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
