"""Multi-CS cluster plane: an asynchronous compute-server fleet over one
disaggregated memory pool (DESIGN.md §11).

Each :class:`ClusterNode` owns a *private* index cache, repair queue, and
LLT view while sharing one memory-side ``TreeState``; the
:class:`Cluster` scheduler interleaves per-CS op batches in rounds and
prices every wave by merging the fleet's RDMA verb traces into one
discrete-event timeline (:func:`repro.core.verbs.merge_traces`), so
cross-CS cache coherence and GLT contention are simulated rather than
assumed.
"""
from repro.cluster.node import ClusterNode
from repro.cluster.sched import Cluster, build_cluster, run_cluster
from repro.cluster.streams import ClusterStreams

__all__ = ["Cluster", "ClusterNode", "ClusterStreams", "build_cluster",
           "run_cluster"]
