"""ClusterNode — one compute server of the multi-CS cluster plane.

A node owns everything the paper gives a compute server privately:

* its **index cache** (:class:`repro.core.cache.IndexCache`) — a private
  replica with its *own* staleness trajectory.  Unlike the single-frontend
  ``ShermanIndex``, a node is never fed remote CSs' ``WriteStats``: it
  learns of remote splits lazily, through version/fence mismatch on its
  own reads or through its periodic sync sweeps
  (``IndexCache.end_round``);
* its **repair queue** (:class:`repro.core.write.RepairQueue`) — the
  B-link half-splits *it* created and must complete;
* its **LLT view** — HOCL conflict grouping runs over the node's own
  batch only (every lane carries this node's CS id), so local wait queues
  and handovers are genuinely private.  Cross-CS contention is *not*
  visible here; it emerges in the scheduler's merged verb timeline
  (DESIGN.md §11).

A node executes op batches against the **shared** memory-side
:class:`~repro.core.tree.TreeState` (state in, state out — the node holds
no tree state) and returns per-phase stats dicts; the scheduler turns
those into verb traces, merges them across the fleet, and prices the
merged timeline.  Nothing here touches netsim.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.api import (_jit_lookup, _jit_range, _jit_range_cached,
                            _jit_repair, _jit_write_phase, write_stats_dict)
from repro.core.cache import IndexCache
from repro.core.tree import TreeConfig, TreeState
from repro.core.write import RepairQueue


class ClusterNode:
    """One compute server: private cache + repair queue + LLT grouping."""

    def __init__(self, cs_id: int, cfg: TreeConfig, *,
                 cache_bytes: int = 64 << 20,
                 cache_levels: Optional[int] = None,
                 cache_sync_every: int = 8,
                 cache_chase_hops: int = 4,
                 sync_rounds: int = 4,
                 kernel_mode: Optional[str] = None):
        self.cs_id = int(cs_id)
        self.cfg = cfg
        self.cache = IndexCache(cfg, cache_bytes, levels=cache_levels,
                                chase_hops=cache_chase_hops,
                                sync_every=cache_sync_every,
                                sync_rounds=sync_rounds,
                                kernel_mode=kernel_mode)
        self.repair = RepairQueue.empty(1)
        self.counters = {
            "ops": 0, "write_ops": 0, "read_ops": 0, "retried_ops": 0,
            "phases": 0, "lookup_ops": 0, "lookup_rtts": 0,
            "leaf_splits": 0, "internal_splits": 0, "root_splits": 0,
            "split_same_ms": 0, "handovers": 0, "hocl_cas": 0,
            "flat_cas": 0, "cache_hits": 0, "cache_misses": 0,
            "cache_stale": 0,
            # per-trace functional totals (pre-merge) — the conservation
            # oracle the merged simulation is checked against
            "verbs": 0, "doorbells": 0, "bytes": 0.0,
        }

    # -- trace attribution (called by the scheduler) -----------------------
    def note_trace(self, trace) -> None:
        """Accumulate one of this CS's traces' functional totals."""
        c = self.counters
        c["verbs"] += trace.n_verbs
        c["doorbells"] += trace.n_doorbells
        c["bytes"] += trace.total_bytes

    # -- write path --------------------------------------------------------
    def _carry_repair(self, n: int) -> None:
        old = self.repair
        fresh = RepairQueue.empty(n)
        k = min(n, old.sep.shape[0])
        self.repair = RepairQueue(
            sep=fresh.sep.at[:k].set(old.sep[:k]),
            child=fresh.child.at[:k].set(old.child[:k]),
            level=fresh.level.at[:k].set(old.level[:k]),
            valid=fresh.valid.at[:k].set(old.valid[:k]))

    def write_batch(self, st: TreeState, keys, vals, is_delete,
                    max_phases: int = 8):
        """Apply one write batch of this CS's threads to the shared state.

        Returns ``(state, phase_stats)``: the new tree state and one
        numpy stats dict per executed phase (``api.write_stats_dict``
        layout — the verb plane's input).  The node's own splits feed its
        cache's invalidation hook; *remote* CSs stay oblivious.
        """
        keys = jnp.asarray(keys, jnp.int32)
        n = keys.shape[0]
        if n == 0:
            return st, []
        vals = jnp.asarray(vals, jnp.int32) if vals is not None else \
            jnp.zeros((n,), jnp.int32)
        is_del = jnp.broadcast_to(jnp.asarray(is_delete, bool), (n,))
        cs = jnp.full((n,), self.cs_id, jnp.int32)
        active = jnp.ones((n,), bool)
        if self.repair.valid.shape[0] != n:
            self._carry_repair(n)
        if self.cache.enabled:
            route_hits = self.cache.route_hits(st, keys)
        else:
            route_hits = np.zeros(n, bool)
        c = self.counters
        c["write_ops"] += n
        c["ops"] += n
        phase_stats = []
        for phase_no in range(max_phases):
            st, done, stats, self.repair = _jit_write_phase(
                self.cfg, st, keys, vals, is_del, active, cs, self.repair)
            phase_stats.append(write_stats_dict(
                stats, np.asarray(active), route_hits, int(st.height)))
            c["phases"] += 1
            if phase_no:
                c["retried_ops"] += int(np.asarray(active).sum())
            self.cache.note_splits(int(stats.n_leaf_splits),
                                   int(stats.n_internal_splits),
                                   int(stats.n_root_splits), st)
            c["leaf_splits"] += int(stats.n_leaf_splits)
            c["internal_splits"] += int(stats.n_internal_splits)
            c["root_splits"] += int(stats.n_root_splits)
            c["split_same_ms"] += int(stats.n_split_same_ms)
            c["handovers"] += int(stats.handovers)
            c["hocl_cas"] += int(stats.hocl_remote_cas)
            c["flat_cas"] += int(stats.flat_remote_cas)
            active = active & ~done
            if not bool(jnp.any(active)):
                break
        if bool(jnp.any(active)):
            raise RuntimeError(f"CS {self.cs_id}: write batch did not "
                               "converge; pool exhausted or max_phases "
                               "too low")
        st = self.drain_repairs(st)
        return st, phase_stats

    def drain_repairs(self, st: TreeState, max_iters: int = 16) -> TreeState:
        """Complete this CS's outstanding B-link half-splits."""
        for _ in range(max_iters):
            if not bool(jnp.any(self.repair.valid)):
                return st
            st, self.repair, ni, nr = _jit_repair(self.cfg, st, self.repair)
            self.counters["internal_splits"] += int(ni)
            self.counters["root_splits"] += int(nr)
            self.cache.note_splits(0, int(ni), int(nr), st)
        if bool(jnp.any(self.repair.valid)):
            raise RuntimeError(f"CS {self.cs_id}: repair queue did not "
                               "drain")
        return st

    # -- read path ---------------------------------------------------------
    def lookup_batch(self, st: TreeState, keys):
        """Point lookups through this CS's private cache.

        Returns ``(values, found, stats)`` where ``stats`` is the read
        trace's input dict (per-lane remote reads + target leaves)."""
        keys = jnp.asarray(keys, jnp.int32)
        n = keys.shape[0]
        c = self.counters
        if self.cache.enabled:
            res, cst = self.cache.lookup(st, keys)
            c["cache_hits"] += int((cst["hit"] & ~cst["stale"]).sum())
            c["cache_misses"] += int((~cst["hit"]).sum())
            c["cache_stale"] += int(cst["stale"].sum())
            reads = np.asarray(cst["remote_reads"])
            sd = dict(active=np.ones(n, bool),
                      cache_hit=cst["hit"] & ~cst["stale"],
                      remote_reads=reads,
                      leaf=np.asarray(res.leaf),
                      height=int(st.height))
        else:
            res = _jit_lookup(self.cfg, st, keys)
            c["cache_misses"] += n
            reads = np.full(n, max(int(st.height), 1), np.int64)
            sd = dict(active=np.ones(n, bool),
                      cache_hit=np.zeros(n, bool),
                      leaf=np.asarray(res.leaf),
                      height=int(st.height))
        c["read_ops"] += n
        c["ops"] += n
        c["lookup_ops"] += n
        c["lookup_rtts"] += int(reads.sum())
        return np.asarray(res.value), np.asarray(res.found), sd

    def scan_batch(self, st: TreeState, lo, count: int,
                   max_leaves: Optional[int] = None):
        """Range scans; the initial descent consults the private cache."""
        lo = jnp.asarray(lo, jnp.int32)
        n = lo.shape[0]
        if max_leaves is None:
            max_leaves = max(4, count)
        if self.cache.enabled:
            res = _jit_range_cached(self.cfg, st, lo, count, max_leaves,
                                    self.cache.image(st))
            hits = np.asarray(res.start_hit)
            self.cache.note_hits(hits)
        else:
            res = _jit_range(self.cfg, st, lo, count, max_leaves)
            hits = np.zeros(n, bool)
        n_leaves = np.asarray(res.leaves_read)
        sd = dict(active=np.ones(n, bool), cache_hit=hits,
                  retries=np.maximum(n_leaves - 1, 0),
                  leaf=np.asarray(res.start_leaf), scan=True,
                  height=int(st.height))
        c = self.counters
        c["read_ops"] += n
        c["ops"] += n
        return (np.asarray(res.keys), np.asarray(res.vals),
                np.asarray(res.n)), sd

    # -- coherence tick ----------------------------------------------------
    def end_round(self, st: TreeState) -> None:
        """One scheduler round elapsed: run the private cache's periodic
        version sweep if due (the node's only non-lazy coherence)."""
        self.cache.end_round(st)

    def take_maintenance(self):
        """Drain the cache's un-priced fill/sweep reads (node, small)."""
        if not self.cache.enabled:
            return 0, 0
        return self.cache.take_maintenance()
