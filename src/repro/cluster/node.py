"""ClusterNode — one compute server of the multi-CS cluster plane.

A node owns everything the paper gives a compute server privately:

* its **index cache** (:class:`repro.core.cache.IndexCache`) — a private
  replica with its *own* staleness trajectory.  Unlike the single-frontend
  ``ShermanIndex``, a node is never fed remote CSs' split outputs: it
  learns of remote splits lazily, through version/fence mismatch on its
  own reads or through its periodic sync sweeps
  (``IndexCache.end_round``);
* its **LLT view** — HOCL conflict grouping keys on the node's CS id, so
  local wait queues and handovers stay genuinely private even inside the
  scheduler's stacked ``[n_cs*B]``-lane write dispatch (every lane
  carries its CS id; :func:`repro.core.hocl.group_by_node` groups by
  ``(cs, node)``).  Cross-CS contention emerges in the merged verb
  timeline (DESIGN.md §11), never here;
* its **functional counters** — per-CS op/verb/cache tallies, including
  the per-trace totals the merged simulation is conservation-checked
  against.

Since PR 5 the *write phases themselves* execute as one stacked
fleet-wide dispatch owned by the scheduler (:mod:`repro.cluster.sched`),
which attributes each phase's per-lane structure back to the owning
node — so this class carries no repair queue anymore (half-splits are
completed at wave scope by the scheduler's shared fixed-capacity queue).
Read batches still run per node because each CS descends through its own
cache image; they are padded to power-of-two buckets
(:func:`repro.core.api.bucket_size`) so each shape compiles once.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.api import (_jit_lookup, _jit_range, _jit_range_cached,
                            bucket_size, pad_to_bucket)
from repro.core.cache import IndexCache
from repro.core.tree import TreeConfig, TreeState


class ClusterNode:
    """One compute server: private cache + LLT grouping + counters."""

    def __init__(self, cs_id: int, cfg: TreeConfig, *,
                 cache_bytes: int = 64 << 20,
                 cache_levels: Optional[int] = None,
                 cache_sync_every: int = 8,
                 cache_chase_hops: int = 4,
                 sync_rounds: int = 4,
                 kernel_mode: Optional[str] = None):
        self.cs_id = int(cs_id)
        self.cfg = cfg
        self.cache = IndexCache(cfg, cache_bytes, levels=cache_levels,
                                chase_hops=cache_chase_hops,
                                sync_every=cache_sync_every,
                                sync_rounds=sync_rounds,
                                kernel_mode=kernel_mode)
        self.counters = {
            "ops": 0, "write_ops": 0, "read_ops": 0, "retried_ops": 0,
            "phases": 0, "lookup_ops": 0, "lookup_reads": 0,
            "leaf_splits": 0, "internal_splits": 0, "root_splits": 0,
            "split_same_ms": 0, "handovers": 0, "hocl_cas": 0,
            "flat_cas": 0, "cache_hits": 0, "cache_misses": 0,
            "cache_stale": 0,
            # per-trace functional totals (pre-merge) — the conservation
            # oracle the merged simulation is checked against
            "verbs": 0, "doorbells": 0, "bytes": 0.0,
        }

    # -- trace attribution (called by the scheduler) -----------------------
    def note_trace(self, trace) -> None:
        """Accumulate one of this CS's traces' functional totals."""
        c = self.counters
        c["verbs"] += trace.n_verbs
        c["doorbells"] += trace.n_doorbells
        c["bytes"] += trace.total_bytes

    def note_write_phase(self, sd: dict, mine: np.ndarray,
                         first_phase: bool, st: TreeState) -> None:
        """Attribute one stacked write phase's per-lane structure to this
        CS (``mine`` = this node's active lanes in the stacked batch).

        Scalar lock counters are rebuilt from the per-lane masks: each
        handover-cycle head is one remote HOCL CAS; a lane at global node
        rank *r* is ``r + 1`` CAS attempts under the flat baseline; every
        non-head lane was served by a handover.  The node's own leaf
        splits feed its cache's invalidation hook; *remote* CSs stay
        oblivious (root splits surface through the root-pointer check on
        the next image use, internal splits through staleness).
        """
        k = int(mine.sum())
        if not k:
            return
        c = self.counters
        c["phases"] += 1
        if not first_phase:
            c["retried_ops"] += k
        heads = int((np.asarray(sd["cycle_head"]) & mine).sum())
        n_leaf = int((np.asarray(sd["split_lane"]) & mine).sum())
        c["leaf_splits"] += n_leaf
        c["split_same_ms"] += int((np.asarray(sd["split_same_ms"])
                                   & mine).sum())
        c["hocl_cas"] += heads
        c["flat_cas"] += int((np.asarray(sd["node_rank"])[mine] + 1).sum())
        c["handovers"] += k - heads
        if n_leaf:
            self.cache.note_splits(n_leaf, 0, 0, st)

    # -- read path ---------------------------------------------------------
    def lookup_batch(self, st: TreeState, keys):
        """Point lookups through this CS's private cache.

        Returns ``(values, found, stats)`` where ``stats`` is the read
        trace's input dict (per-lane remote reads + target leaves, padded
        to the dispatch bucket with an ``active`` prefix mask)."""
        keys = jnp.asarray(keys, jnp.int32)
        n = keys.shape[0]
        m = bucket_size(n)
        kp = pad_to_bucket(keys, m)
        active = np.arange(m) < n
        c = self.counters
        if self.cache.enabled:
            res, cst = self.cache.lookup(st, kp, n_valid=n)
            hit, stale = cst["hit"][:n], cst["stale"][:n]
            c["cache_hits"] += int((hit & ~stale).sum())
            c["cache_misses"] += int((~hit).sum())
            c["cache_stale"] += int(stale.sum())
            reads = cst["remote_reads"]
            n_reads = int(reads[:n].sum())
            sd = dict(active=active,
                      cache_hit=cst["hit"] & ~cst["stale"],
                      remote_reads=reads,
                      leaf=np.asarray(res.leaf),
                      height=int(st.height))
        else:
            res = _jit_lookup(self.cfg, st, kp)
            c["cache_misses"] += n
            n_reads = n * max(int(st.height), 1)
            sd = dict(active=active,
                      cache_hit=np.zeros(m, bool),
                      leaf=np.asarray(res.leaf),
                      height=int(st.height))
        c["read_ops"] += n
        c["ops"] += n
        c["lookup_ops"] += n
        c["lookup_reads"] += n_reads
        return np.asarray(res.value)[:n], np.asarray(res.found)[:n], sd

    def scan_batch(self, st: TreeState, lo, count: int,
                   max_leaves: Optional[int] = None):
        """Range scans; the initial descent consults the private cache."""
        lo = jnp.asarray(lo, jnp.int32)
        n = lo.shape[0]
        m = bucket_size(n)
        lo_p = pad_to_bucket(lo, m)
        if max_leaves is None:
            max_leaves = max(4, count)
        if self.cache.enabled:
            res = _jit_range_cached(self.cfg, st, lo_p, count, max_leaves,
                                    self.cache.image(st))
            hits = np.asarray(res.start_hit)
            self.cache.note_hits(hits[:n])
        else:
            res = _jit_range(self.cfg, st, lo_p, count, max_leaves)
            hits = np.zeros(m, bool)
        n_leaves = np.asarray(res.leaves_read)
        sd = dict(active=np.arange(m) < n, cache_hit=hits,
                  retries=np.maximum(n_leaves - 1, 0),
                  leaf=np.asarray(res.start_leaf), scan=True,
                  height=int(st.height))
        c = self.counters
        c["read_ops"] += n
        c["ops"] += n
        return (np.asarray(res.keys)[:n], np.asarray(res.vals)[:n],
                np.asarray(res.n)[:n]), sd

    # -- coherence tick ----------------------------------------------------
    def end_round(self, st: TreeState) -> None:
        """One scheduler round elapsed: run the private cache's periodic
        version sweep if due (the node's only non-lazy coherence)."""
        self.cache.end_round(st)

    def take_maintenance(self):
        """Drain the cache's un-priced fill/sweep reads (node, small)."""
        if not self.cache.enabled:
            return 0, 0
        return self.cache.take_maintenance()
