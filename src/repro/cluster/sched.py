"""The cluster scheduler: a fleet of ClusterNodes over one memory pool.

One **round** (scheduler tick) interleaves one op batch per compute
server (DESIGN.md §11):

1. *Functional plane* — per-CS batches apply to the shared
   :class:`~repro.core.tree.TreeState` in CS order (CS order is arrival
   order, the cluster analogue of §8's lane-order rule).  Each node uses
   only its private cache / repair queue / LLT grouping; remote splits
   reach it lazily (stale reads, periodic sweeps), never as shared
   ``WriteStats``.
2. *Performance plane* — each node's per-phase verb traces are **merged**
   (:func:`repro.core.verbs.merge_traces`) and replayed in one
   discrete-event timeline against the shared per-MS NIC and atomic-unit
   FIFOs.  Cross-CS GLT serialization, FG+ retry storms clogging the
   atomic unit, and HOCL's handover savings are emergent queueing, not
   formulas.

The scheduler keeps two tallies per run: the **merged** totals the event
loop reports and the **functional** per-CS trace totals accumulated
before merging.  Their equality (verbs, doorbells, bytes) is the
cluster's conservation invariant, exported as ``conservation_ok``.
"""
from __future__ import annotations

import math
import warnings
from typing import Optional, Sequence

import numpy as np

from repro.cluster.node import ClusterNode
from repro.cluster.streams import ClusterStreams
from repro.core import hocl, netsim, verbs as V
from repro.core.netsim import Features, NetConfig, SHERMAN
from repro.core.tree import TreeConfig, TreeState, bulkload
from repro.workloads.keygen import scramble
from repro.workloads.spec import OP_KINDS, WorkloadSpec

VAL_MASK = (1 << 30) - 1


class Cluster:
    """A multi-CS simulation plane over one shared memory-side state."""

    def __init__(self, cfg: TreeConfig, state: TreeState,
                 features: Features = SHERMAN,
                 net: Optional[NetConfig] = None, *,
                 n_clients: int = 64,
                 cache_bytes: int = 64 << 20,
                 cache_levels: Optional[int] = None,
                 sync_rounds: int = 4,
                 kernel_mode: Optional[str] = None):
        self.cfg = cfg
        self.state = state
        self.features = features
        self.net = net or NetConfig()
        n_cs = max(1, min(cfg.n_cs, int(n_clients)))
        self.per_cs = max(1, -(-int(n_clients) // n_cs))
        self.n_clients = self.per_cs * n_cs     # realized lanes per round
        if self.n_clients != int(n_clients):
            warnings.warn(
                f"n_clients={n_clients} is not a multiple of the "
                f"{n_cs}-CS fleet; running {self.n_clients} client "
                f"threads ({n_cs} CS x {self.per_cs})", stacklevel=2)
        self.nodes = [
            ClusterNode(i, cfg, cache_bytes=cache_bytes,
                        cache_levels=cache_levels, sync_rounds=sync_rounds,
                        kernel_mode=kernel_mode)
            for i in range(n_cs)]
        # merged-timeline totals (the priced side)
        self.counters = {
            "msgs": 0, "verbs": 0, "doorbells": 0, "bytes": 0.0,
            "cas_msgs": 0, "sim_time_s": 0.0, "merged_waves": 0,
            "rounds": 0, "cross_cs_conflicts": 0,
        }
        self.latencies_write: list[np.ndarray] = []
        self.latencies_read: list[np.ndarray] = []
        self.rtts_write: list[np.ndarray] = []
        self.write_bytes: list[np.ndarray] = []

    @property
    def n_cs(self) -> int:
        return len(self.nodes)

    # -- constructors ------------------------------------------------------
    @classmethod
    def build(cls, cfg: TreeConfig, keys, vals, fill: float = 0.8,
              **kw) -> "Cluster":
        return cls(cfg, bulkload(cfg, keys, vals, fill=fill), **kw)

    # -- merged pricing ----------------------------------------------------
    def _simulate_merged(self, tagged, kind: str) -> None:
        """Merge per-CS traces (``tagged`` = [(cs, trace), ...]) and price
        the shared timeline; attribute functional totals per CS."""
        tagged = [(cs, t) for cs, t in tagged if t.n_verbs]
        if not tagged:
            return
        for cs, t in tagged:
            self.nodes[cs].note_trace(t)
        sim, merged = netsim.price_merged_phase(
            [t for _, t in tagged], self.features, self.net, self.cfg)
        c = self.counters
        c["msgs"] += sim["msgs"]
        c["verbs"] += sim["verbs"]
        c["doorbells"] += sim["doorbells"]
        c["bytes"] += sim["bytes"]
        c["cas_msgs"] += sim["cas_msgs"]
        c["sim_time_s"] += sim["makespan_s"]
        c["merged_waves"] += 1
        if kind == "write":
            self.latencies_write.append(sim["latency_s"])
            self.rtts_write.append(sim["rtts"])
            self.write_bytes.append(sim["write_bytes"])
        elif kind == "read":
            self.latencies_read.append(sim["latency_s"])

    def _maintenance(self) -> None:
        """Price the fleet's cache maintenance (fills + sweeps), merged."""
        tagged = []
        for i, node in enumerate(self.nodes):
            nr, sr = node.take_maintenance()
            if nr or sr:
                tagged.append((i, V.maintenance_trace(
                    nr, sr, self.cfg.n_ms, self.cfg.node_bytes,
                    self.net.small_io_bytes,
                    rows_ms=node.cache.rows_ms())))
        self._simulate_merged(tagged, "maint")

    # -- cluster waves -----------------------------------------------------
    def write_wave(self, keys_by_cs: Sequence, vals_by_cs=None,
                   is_delete: bool = False) -> None:
        """One cluster write wave: every CS's batch, applied in CS order,
        priced phase-by-phase in one merged timeline."""
        per_cs_phases: list[list] = []
        for i, node in enumerate(self.nodes):
            keys = keys_by_cs[i] if i < len(keys_by_cs) else None
            if keys is None or len(keys) == 0:
                per_cs_phases.append([])
                continue
            vals = vals_by_cs[i] if vals_by_cs is not None else None
            self.state, phases = node.write_batch(self.state, keys, vals,
                                                  is_delete)
            per_cs_phases.append(phases)
        leaves = [np.asarray(p[0]["leaf"]) for p in per_cs_phases if p]
        if len(leaves) > 1:
            self.counters["cross_cs_conflicts"] += \
                hocl.cross_cs_contention(leaves)["contended_nodes"]
        for k in range(max((len(p) for p in per_cs_phases), default=0)):
            tagged = [(i, netsim.transformed_write_trace(
                p[k], self.features, self.net, self.cfg))
                for i, p in enumerate(per_cs_phases) if len(p) > k]
            self._simulate_merged(tagged, "write")
        self._maintenance()

    def lookup_wave(self, keys_by_cs: Sequence) -> list:
        """One cluster lookup wave; returns ``(values, found)`` per CS."""
        tagged, out = [], []
        for i, node in enumerate(self.nodes):
            keys = keys_by_cs[i] if i < len(keys_by_cs) else None
            if keys is None or len(keys) == 0:
                out.append((np.zeros(0, np.int32), np.zeros(0, bool)))
                continue
            vals, found, sd = node.lookup_batch(self.state, keys)
            tagged.append((i, netsim.read_trace_from_stats(sd, self.cfg)))
            out.append((vals, found))
        self._simulate_merged(tagged, "read")
        self._maintenance()
        return out

    def scan_wave(self, lo_by_cs: Sequence, count: int,
                  max_leaves: Optional[int] = None) -> list:
        """One cluster scan wave; returns ``(keys, vals, n)`` per CS."""
        tagged, out = [], []
        for i, node in enumerate(self.nodes):
            lo = lo_by_cs[i] if i < len(lo_by_cs) else None
            if lo is None or len(lo) == 0:
                out.append(None)
                continue
            res, sd = node.scan_batch(self.state, lo, count, max_leaves)
            tagged.append((i, netsim.read_trace_from_stats(sd, self.cfg)))
            out.append(res)
        self._simulate_merged(tagged, "read")
        self._maintenance()
        return out

    def end_round(self) -> None:
        """Close one scheduler tick: per-CS coherence sweeps, then price
        any maintenance they generated."""
        for node in self.nodes:
            node.end_round(self.state)
        self._maintenance()
        self.counters["rounds"] += 1

    # -- reporting ---------------------------------------------------------
    def node_totals(self) -> dict:
        """Sum of the per-CS functional counters."""
        keys = self.nodes[0].counters.keys()
        return {k: sum(n.counters[k] for n in self.nodes) for k in keys}

    def conservation_ok(self) -> bool:
        """Merged-timeline totals == sum of per-CS functional trace
        totals (verbs, doorbells, bytes) — the cluster invariant."""
        nt = self.node_totals()
        return (self.counters["verbs"] == nt["verbs"]
                and self.counters["doorbells"] == nt["doorbells"]
                and math.isclose(self.counters["bytes"], nt["bytes"],
                                 rel_tol=1e-9, abs_tol=1e-6))

    def combined_counters(self) -> dict:
        """One flat counter dict: merged-timeline totals + per-CS sums —
        a superset of ``ShermanIndex.counters`` so cluster runs share the
        BENCH json schema."""
        nt = self.node_totals()
        out = dict(self.counters)
        for k in ("phases", "write_ops", "read_ops", "retried_ops",
                  "lookup_ops", "lookup_rtts", "leaf_splits",
                  "internal_splits", "root_splits", "split_same_ms",
                  "handovers", "hocl_cas", "flat_cas", "cache_hits",
                  "cache_misses", "cache_stale"):
            out[k] = nt[k]
        return out

    def throughput_mops(self) -> float:
        t = self.counters["sim_time_s"]
        n = self.node_totals()["ops"]
        return n / t / 1e6 if t else 0.0


def build_cluster(features: Features, cfg: TreeConfig, *,
                  n_clients: int, records: int, keyspace: int = 1 << 20,
                  cache_bytes: int = 64 << 20,
                  cache_levels: Optional[int] = None,
                  sync_rounds: int = 4, seed: int = 0,
                  fill: float = 0.8,
                  net: Optional[NetConfig] = None) -> Cluster:
    """Load phase: bulk-load ``records`` scrambled records into the shared
    pool and stand up the CS fleet (mirrors ``workloads.build_index``)."""
    rng = np.random.default_rng(seed)
    keys = scramble(np.arange(records, dtype=np.int64), keyspace)
    vals = rng.integers(0, VAL_MASK, size=records)
    return Cluster.build(cfg, keys, vals, fill=fill, features=features,
                         net=net, n_clients=n_clients,
                         cache_bytes=cache_bytes, cache_levels=cache_levels,
                         sync_rounds=sync_rounds)


def run_cluster(cluster: Cluster, spec: WorkloadSpec, *,
                partitioned: bool = False, seed: int = 1,
                keyspace: int = 1 << 20) -> int:
    """Drive ``spec``'s op mix through the cluster in scheduler rounds.

    Each round hands every CS a ``per_cs``-lane batch from its private
    stream (op mix realized per CS via the salted remainder rotation, so
    even one-lane batches mix over rounds) and executes the waves in a
    fixed kind order (scan, read, rmw, update, delete, insert — the
    engine's order).  Returns ``(done, op_counts)``: the number of client
    ops issued and the realized per-kind mix.
    """
    streams = ClusterStreams(spec, cluster.n_cs, keyspace=keyspace,
                             partitioned=partitioned, seed=seed)
    n_cs, per_cs = cluster.n_cs, cluster.per_cs
    ops_per_round = per_cs * n_cs
    rounds = max(1, -(-spec.ops // ops_per_round))
    done = 0
    op_counts = {k: 0 for k in OP_KINDS}
    for r in range(rounds):
        counts = [spec.batch_counts(per_cs, salt=r * n_cs + cs)
                  for cs in range(n_cs)]

        def gather(kind, draw):
            return [draw(cs, counts[cs][kind]) if counts[cs][kind] else None
                    for cs in range(n_cs)]

        if any(c["scan"] for c in counts):
            cluster.scan_wave(gather("scan", streams.draw),
                              count=spec.scan_len,
                              max_leaves=max(4, spec.scan_len))
        if any(c["read"] for c in counts):
            cluster.lookup_wave(gather("read", streams.draw))
        if any(c["rmw"] for c in counts):
            keys = gather("rmw", streams.draw)
            got = cluster.lookup_wave(keys)
            vals = [((g.astype(np.int64) + 1) & VAL_MASK)
                    if k is not None else None
                    for k, (g, _) in zip(keys, got)]
            cluster.write_wave(keys, vals)
        if any(c["update"] for c in counts):
            keys = gather("update", streams.draw)
            vals = [streams.rngs[cs].integers(0, VAL_MASK, k.size)
                    if k is not None else None
                    for cs, k in enumerate(keys)]
            cluster.write_wave(keys, vals)
        if any(c["delete"] for c in counts):
            cluster.write_wave(gather("delete", streams.draw), None,
                               is_delete=True)
        if any(c["insert"] for c in counts):
            keys = gather("insert", streams.draw_insert)
            vals = [streams.rngs[cs].integers(0, VAL_MASK, k.size)
                    if k is not None else None
                    for cs, k in enumerate(keys)]
            cluster.write_wave(keys, vals)
        cluster.end_round()
        for c in counts:
            for k in OP_KINDS:
                op_counts[k] += c[k]
        done += sum(sum(c.values()) for c in counts)
    return done, {k: v for k, v in op_counts.items() if v}
