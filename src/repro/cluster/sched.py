"""The cluster scheduler: a fleet of ClusterNodes over one memory pool.

One **round** (scheduler tick) interleaves one op batch per compute
server (DESIGN.md §11):

1. *Functional plane* — the fleet's write batches execute as **one
   stacked ``[n_cs*B]``-lane dispatch** per phase: every lane carries its
   CS id, so HOCL's LLT grouping keeps wait queues private per CS while
   the batch applies in lane order (CS order is arrival order, the
   cluster analogue of §8's lane-order rule — intra-batch dedupe keeps
   the last lane, i.e. the last CS, exactly like the old sequential
   apply).  The stacked batch is padded to a power-of-two bucket and the
   shared fixed-capacity repair queue keeps every phase shape-stable, so
   a cluster wave costs one jit dispatch per phase instead of ``n_cs``
   separate JAX calls.  Each node still uses only its private cache
   (write routing probes each CS's own image for its own lanes); remote
   splits reach a CS lazily (stale reads, periodic sweeps), never as
   shared split outputs.  Read waves stay per-CS — each descends through
   its own cache image — but are bucket-padded so they too compile once.
2. *Performance plane* — each phase's per-lane structure is split back
   into per-CS stats (the lane's CS id masks the stacked arrays), turned
   into per-CS verb traces, **merged**
   (:func:`repro.core.verbs.merge_traces`) and replayed in one
   discrete-event timeline against the shared per-MS NIC and atomic-unit
   FIFOs.  Cross-CS GLT serialization, FG+ retry storms clogging the
   atomic unit, and HOCL's handover savings are emergent queueing, not
   formulas.

The scheduler keeps two tallies per run: the **merged** totals the event
loop reports and the **functional** per-CS trace totals accumulated
before merging.  Their equality (verbs, doorbells, bytes) is the
cluster's conservation invariant, exported as ``conservation_ok``.
"""
from __future__ import annotations

import hashlib
import math
import warnings
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.cluster.node import ClusterNode
from repro.cluster.streams import ClusterStreams
from repro.core import hocl, netsim, verbs as V
from repro.core.api import (REPAIR_CAP, _jit_write_phase, bucket_size,
                            pad_to_bucket, run_repair_drain,
                            write_stats_dict)
from repro.core.netsim import Features, NetConfig, SHERMAN
from repro.core.tree import TreeConfig, TreeState, bulkload
from repro.core.write import RepairQueue
from repro.workloads.keygen import scramble
from repro.workloads.spec import OP_KINDS, WorkloadSpec

VAL_MASK = (1 << 30) - 1


class Cluster:
    """A multi-CS simulation plane over one shared memory-side state."""

    def __init__(self, cfg: TreeConfig, state: TreeState,
                 features: Features = SHERMAN,
                 net: Optional[NetConfig] = None, *,
                 n_clients: int = 64,
                 cache_bytes: int = 64 << 20,
                 cache_levels: Optional[int] = None,
                 sync_rounds: int = 4,
                 kernel_mode: Optional[str] = None):
        self.cfg = cfg
        self.state = state
        self.features = features
        self.net = net or NetConfig()
        n_cs = max(1, min(cfg.n_cs, int(n_clients)))
        self.per_cs = max(1, -(-int(n_clients) // n_cs))
        self.n_clients = self.per_cs * n_cs     # realized lanes per round
        if self.n_clients != int(n_clients):
            warnings.warn(
                f"n_clients={n_clients} is not a multiple of the "
                f"{n_cs}-CS fleet; running {self.n_clients} client "
                f"threads ({n_cs} CS x {self.per_cs})", stacklevel=2)
        self.nodes = [
            ClusterNode(i, cfg, cache_bytes=cache_bytes,
                        cache_levels=cache_levels, sync_rounds=sync_rounds,
                        kernel_mode=kernel_mode)
            for i in range(n_cs)]
        # the wave-scope repair queue: half-splits of the stacked dispatch,
        # fixed capacity so every phase shape compiles once
        self.repair = RepairQueue.empty(REPAIR_CAP)
        self._repair_backlog = 0
        # merged-timeline totals (the priced side) + wave-scope structure
        self.counters = {
            "msgs": 0, "verbs": 0, "doorbells": 0, "bytes": 0.0,
            "cas_msgs": 0, "sim_time_s": 0.0, "merged_waves": 0,
            "rounds": 0, "cross_cs_conflicts": 0,
            "stacked_phases": 0, "internal_splits": 0, "root_splits": 0,
        }
        self.latencies_write: list[np.ndarray] = []
        self.latencies_read: list[np.ndarray] = []
        self.doorbells_write: list[np.ndarray] = []
        self.write_bytes: list[np.ndarray] = []
        # open-loop serving plane (enable_open_loop; DESIGN.md §12):
        self.clock: Optional[netsim.ServerClock] = None
        self.queue_write: list[np.ndarray] = []   # per-op queueing delay
        self.queue_read: list[np.ndarray] = []
        self.last_read_comp: dict = {}  # cs -> absolute lookup completions
        self.trace_log: Optional[list] = None     # merged-trace digests
        # opt-in observability plane (repro.obs, DESIGN.md §14): attach a
        # Recorder here and every merged wave captures its timeline
        self.recorder = None

    @property
    def n_cs(self) -> int:
        return len(self.nodes)

    # -- constructors ------------------------------------------------------
    @classmethod
    def build(cls, cfg: TreeConfig, keys, vals, fill: float = 0.8,
              **kw) -> "Cluster":
        return cls(cfg, bulkload(cfg, keys, vals, fill=fill), **kw)

    # -- open-loop mode / trace digests ------------------------------------
    def enable_open_loop(self) -> None:
        """Switch the performance plane onto one absolute timeline
        (the serving plane, DESIGN.md §12): waves replay against a
        carried per-MS :class:`~repro.core.netsim.ServerClock`, per-op
        sojourns are measured from explicit arrival timestamps, and
        ``sim_time_s`` becomes the absolute horizon (max completion)
        instead of a sum of per-phase makespans."""
        self.clock = netsim.ServerClock.fresh(self.cfg.n_ms)
        self.clock.recorder = self.recorder

    def record_traces(self) -> None:
        """Log a structural digest of every merged trace — everything
        but the ``at`` release floors, which are *when*, not *what* — so
        open- and closed-loop runs can be compared wave-for-wave
        (the t=0 differential test in tests/test_serve_queueing.py)."""
        self.trace_log = []

    @staticmethod
    def _trace_digest(kind: str, merged) -> tuple:
        h = hashlib.sha1()
        for a in (merged.kind, merged.role, merged.ms, merged.nbytes,
                  merged.lane, merged.doorbell, merged.dep, merged.dep2):
            h.update(np.ascontiguousarray(a).tobytes())
        return (kind, merged.n_verbs, merged.n_doorbells, h.hexdigest())

    # -- merged pricing ----------------------------------------------------
    def _simulate_merged(self, tagged, kind: str, arrivals=None):
        """Merge per-CS traces (``tagged`` = [(cs, trace), ...]) and price
        the shared timeline; attribute functional totals per CS.

        Closed loop (default): every wave starts its own timeline at t=0
        and ``sim_time_s`` accumulates makespans.  Open loop
        (:meth:`enable_open_loop`): the wave replays on the carried
        absolute :class:`ServerClock` timeline; ``arrivals`` (a dict
        ``cs -> per-lane arrival seconds``, aligned with that CS's trace
        lanes) turns absolute completions into per-op sojourns and the
        replay's NIC/atomic waits into queueing-delay samples.  Returns
        ``(sim, kept)`` where ``kept`` lists the CS ids actually merged
        (in lane order) — the write wave uses it to fold multi-phase
        completions back onto ops.
        """
        tagged = [(cs, t) for cs, t in tagged if t.n_verbs]
        if not tagged:
            return None, []
        for cs, t in tagged:
            self.nodes[cs].note_trace(t)
        rec = self.recorder
        if rec is not None:
            rec.set_phase(kind)
            if self.clock is None:
                # closed loop: place this wave's relative timeline at the
                # accumulated sim time (open loop is already absolute)
                rec.sync_cursor(self.counters["sim_time_s"])
        sim, merged = netsim.price_merged_phase(
            [t for _, t in tagged], self.features, self.net, self.cfg,
            clock=self.clock, recorder=rec)
        if self.trace_log is not None:
            self.trace_log.append(self._trace_digest(kind, merged))
        c = self.counters
        c["msgs"] += sim["msgs"]
        c["verbs"] += sim["verbs"]
        c["doorbells"] += sim["doorbells"]
        c["bytes"] += sim["bytes"]
        c["cas_msgs"] += sim["cas_msgs"]
        if self.clock is not None:
            # absolute timeline: the horizon is the latest completion
            c["sim_time_s"] = max(c["sim_time_s"], sim["makespan_s"])
        else:
            c["sim_time_s"] += sim["makespan_s"]
        c["merged_waves"] += 1
        if kind == "write":
            self.doorbells_write.append(sim["lane_doorbells"])
            self.write_bytes.append(sim["write_bytes"])
            if self.clock is None:
                self.latencies_write.append(sim["latency_s"])
            # open loop: write_wave folds multi-phase completions into
            # per-op sojourns itself (one sample per op, not per phase)
        elif kind == "read":
            if self.clock is None:
                self.latencies_read.append(sim["latency_s"])
            elif arrivals is not None:
                off = 0
                self.last_read_comp = {}
                for cs, t in tagged:
                    nl = t.n_lanes
                    comp = sim["latency_s"][off:off + nl]
                    self.last_read_comp[cs] = comp
                    self.latencies_read.append(comp - arrivals[cs])
                    self.queue_read.append(
                        sim["lane_queue_s"][off:off + nl])
                    off += nl
        return sim, [cs for cs, _ in tagged]

    def _maintenance(self) -> None:
        """Price the fleet's cache maintenance (fills + sweeps), merged.
        In open-loop mode the background verbs are released at the
        current horizon — maintenance generated by a wave cannot start
        before the wave was admitted."""
        tagged = []
        for i, node in enumerate(self.nodes):
            nr, sr = node.take_maintenance()
            if nr or sr:
                tagged.append((i, V.maintenance_trace(
                    nr, sr, self.cfg.n_ms, self.cfg.node_bytes,
                    self.net.small_io_bytes,
                    rows_ms=node.cache.rows_ms())))
        if self.clock is not None and tagged:
            t0 = self.counters["sim_time_s"]
            tagged = [(i, V.shift_release(t, np.zeros(t.n_lanes), t0))
                      for i, t in tagged]
        self._simulate_merged(tagged, "maint")

    # -- cluster waves -----------------------------------------------------
    def write_wave(self, keys_by_cs: Sequence, vals_by_cs=None,
                   is_delete: bool = False, max_phases: int = 8,
                   arrivals_by_cs=None, drain: bool = True) -> None:
        """One cluster write wave: every CS's batch, stacked into a single
        ``[n_cs*B]``-lane jitted dispatch per phase, priced phase-by-phase
        in one merged timeline.

        In open-loop mode ``arrivals_by_cs[i]`` gives CS *i*'s per-op
        release times (absolute seconds); each retry phase is released
        by the op's previous phase completion (``release = max(release,
        completion)``), and one sojourn/queueing sample per *op* (not
        per phase) lands in ``latencies_write`` / ``queue_write``.

        ``drain=False`` leaves the wave's half-splits *pending* in the
        shared repair queue instead of completing them — the chaos plane
        uses this to crash a memory server while GLT handovers and
        repairs are in flight (DESIGN.md §13); the B-link invariant
        keeps the tree correct until they are re-derived or replayed."""
        segs = []
        for i in range(self.n_cs):
            k = keys_by_cs[i] if i < len(keys_by_cs) else None
            if k is None or len(k) == 0:
                continue
            k = np.asarray(k, np.int32)
            if vals_by_cs is not None and vals_by_cs[i] is not None:
                v = np.asarray(vals_by_cs[i], np.int32)
            else:
                v = np.zeros(k.size, np.int32)
            segs.append((i, k, v))
        if not segs:
            return
        keys = np.concatenate([k for _, k, _ in segs])
        vals = np.concatenate([v for _, _, v in segs])
        cs_l = np.concatenate([np.full(k.size, i, np.int32)
                               for i, k, _ in segs])
        n = keys.size
        m = bucket_size(n)
        keys_j = pad_to_bucket(jnp.asarray(keys), m)
        vals_j = pad_to_bucket(jnp.asarray(vals), m)
        cs_j = pad_to_bucket(jnp.asarray(cs_l), m)
        cs_np = np.pad(cs_l, (0, m - n), constant_values=-1)
        is_del = jnp.broadcast_to(jnp.asarray(bool(is_delete)), (m,))
        active = jnp.arange(m) < n
        # write routing probes each CS's private image for its own lanes;
        # each CS routes only its own (bucket-padded) segment, so the
        # work stays O(total lanes) instead of O(n_cs * total lanes)
        route_hits = np.zeros(m, bool)
        off = 0
        for i, k, _ in segs:
            node = self.nodes[i]
            node.counters["write_ops"] += k.size
            node.counters["ops"] += k.size
            if node.cache.enabled:
                kp = pad_to_bucket(jnp.asarray(k), bucket_size(k.size))
                h = node.cache.route_hits(self.state, kp, n_valid=k.size)
                route_hits[off:off + k.size] = h[:k.size]
            off += k.size
        phase_sds = []
        for phase_no in range(max_phases):
            self.state, done, stats, self.repair = _jit_write_phase(
                self.cfg, self.state, keys_j, vals_j, is_del, active,
                cs_j, self.repair)
            act_np = np.asarray(active)
            sd = write_stats_dict(stats, act_np, route_hits,
                                  int(self.state.height))
            phase_sds.append(sd)
            c = self.counters
            c["stacked_phases"] += 1
            c["internal_splits"] += int(stats.n_internal_splits)
            c["root_splits"] += int(stats.n_root_splits)
            self._repair_backlog = int(stats.repair_backlog)
            for i, _, _ in segs:
                self.nodes[i].note_write_phase(
                    sd, act_np & (cs_np == i),
                    first_phase=phase_no == 0, st=self.state)
            active = active & ~done
            if not bool(jnp.any(active)):
                break
        if bool(jnp.any(active)):
            raise RuntimeError("cluster write wave did not converge; "
                               "pool exhausted or max_phases too low")
        if drain:
            self.drain_repairs()
        # cross-CS conflict decomposition over the first phase's targets
        sd0 = phase_sds[0]
        leaves = [np.asarray(sd0["leaf"])[sd0["active"] & (cs_np == i)]
                  for i, _, _ in segs]
        if sum(1 for lv in leaves if lv.size) > 1:
            self.counters["cross_cs_conflicts"] += \
                hocl.cross_cs_contention(leaves)["contended_nodes"]
        # performance plane: split each phase back into per-CS traces
        open_mode = self.clock is not None
        if open_mode:
            arr_full = np.zeros(m, np.float64)
            off = 0
            for i, k, _ in segs:
                if arrivals_by_cs is not None and \
                        arrivals_by_cs[i] is not None:
                    arr_full[off:off + k.size] = np.asarray(
                        arrivals_by_cs[i], np.float64)
                off += k.size
            op_comp = arr_full.copy()      # per-op absolute completion
            op_queue = np.zeros(m)         # per-op NIC/atomic queueing
            release = arr_full.copy()      # next phase's release floor
        for sd in phase_sds:
            masks = {i: sd["active"] & (cs_np == i) for i, _, _ in segs}
            tagged = []
            for i, _, _ in segs:
                t = netsim.transformed_write_trace(
                    dict(sd, active=masks[i]), self.features, self.net,
                    self.cfg)
                if open_mode and t.n_verbs:
                    t = V.shift_release(t, release[masks[i]])
                tagged.append((i, t))
            sim, kept = self._simulate_merged(tagged, "write")
            if open_mode and sim is not None:
                lanes = {i: t.n_lanes for i, t in tagged if t.n_verbs}
                off = 0
                for i in kept:
                    nl = lanes[i]
                    idxs = np.flatnonzero(masks[i])[:nl]
                    op_comp[idxs] = sim["latency_s"][off:off + nl]
                    op_queue[idxs] += sim["lane_queue_s"][off:off + nl]
                    off += nl
                release = np.maximum(release, op_comp)
        if open_mode:
            off = 0
            for i, k, _ in segs:
                sl = slice(off, off + k.size)
                self.latencies_write.append(op_comp[sl] - arr_full[sl])
                self.queue_write.append(op_queue[sl])
                off += k.size
        self._maintenance()

    def drain_repairs(self, max_iters: int = 16, sync_every: int = 4):
        """Complete the wave's outstanding B-link half-splits (shared
        fixed-capacity queue, fleet scope).  Mirrors
        ``ShermanIndex.drain_repairs``: the jitted step returns the
        pending count, so the host syncs every ``sync_every`` iterations
        at most.  Repair-induced splits stay unannounced to the private
        caches — a root move surfaces through the root-pointer check on
        the next image use, internal splits through staleness (the lazy
        coherence protocol)."""
        if not self._repair_backlog:
            return
        (self.state, self.repair, n_int, n_root,
         self._repair_backlog) = run_repair_drain(
            self.cfg, self.state, self.repair, max_iters, sync_every)
        self.counters["internal_splits"] += n_int
        self.counters["root_splits"] += n_root
        if self._repair_backlog:
            raise RuntimeError("cluster repair queue did not drain")

    def _shift_reads(self, tagged, arrivals_by_cs):
        """Open-loop read release: trace lanes align with the CS's input
        key order (node batches are bucket-padded, actives first), so a
        per-lane shift by that CS's arrival times is exact."""
        if self.clock is None or arrivals_by_cs is None:
            return tagged, None
        arrs, shifted = {}, []
        for i, t in tagged:
            a = np.asarray(arrivals_by_cs[i], np.float64)[:t.n_lanes]
            arrs[i] = a
            shifted.append((i, V.shift_release(t, a)))
        return shifted, arrs

    def lookup_wave(self, keys_by_cs: Sequence,
                    arrivals_by_cs=None) -> list:
        """One cluster lookup wave; returns ``(values, found)`` per CS."""
        tagged, out = [], []
        for i, node in enumerate(self.nodes):
            keys = keys_by_cs[i] if i < len(keys_by_cs) else None
            if keys is None or len(keys) == 0:
                out.append((np.zeros(0, np.int32), np.zeros(0, bool)))
                continue
            vals, found, sd = node.lookup_batch(self.state, keys)
            tagged.append((i, netsim.read_trace_from_stats(sd, self.cfg)))
            out.append((vals, found))
        tagged, arrs = self._shift_reads(tagged, arrivals_by_cs)
        self._simulate_merged(tagged, "read", arrivals=arrs)
        self._maintenance()
        return out

    def scan_wave(self, lo_by_cs: Sequence, count: int,
                  max_leaves: Optional[int] = None,
                  arrivals_by_cs=None) -> list:
        """One cluster scan wave; returns ``(keys, vals, n)`` per CS."""
        tagged, out = [], []
        for i, node in enumerate(self.nodes):
            lo = lo_by_cs[i] if i < len(lo_by_cs) else None
            if lo is None or len(lo) == 0:
                out.append(None)
                continue
            res, sd = node.scan_batch(self.state, lo, count, max_leaves)
            tagged.append((i, netsim.read_trace_from_stats(sd, self.cfg)))
            out.append(res)
        tagged, arrs = self._shift_reads(tagged, arrivals_by_cs)
        self._simulate_merged(tagged, "read", arrivals=arrs)
        self._maintenance()
        return out

    def end_round(self) -> None:
        """Close one scheduler tick: per-CS coherence sweeps, then price
        any maintenance they generated."""
        for node in self.nodes:
            node.end_round(self.state)
        self._maintenance()
        self.counters["rounds"] += 1

    # -- reporting ---------------------------------------------------------
    def node_totals(self) -> dict:
        """Sum of the per-CS functional counters."""
        keys = self.nodes[0].counters.keys()
        return {k: sum(n.counters[k] for n in self.nodes) for k in keys}

    def conservation_ok(self) -> bool:
        """Merged-timeline totals == sum of per-CS functional trace
        totals (verbs, doorbells, bytes) — the cluster invariant."""
        nt = self.node_totals()
        return (self.counters["verbs"] == nt["verbs"]
                and self.counters["doorbells"] == nt["doorbells"]
                and math.isclose(self.counters["bytes"], nt["bytes"],
                                 rel_tol=1e-9, abs_tol=1e-6))

    def combined_counters(self) -> dict:
        """One flat counter dict: merged-timeline totals + per-CS sums —
        a superset of ``ShermanIndex.counters`` so cluster runs share the
        BENCH json schema.  Wave-scope structure (stacked phases,
        repair-cascade splits) accrues on the cluster's own counters and
        is added to the per-CS sums here."""
        nt = self.node_totals()
        out = dict(self.counters)
        for k in ("phases", "write_ops", "read_ops", "retried_ops",
                  "lookup_ops", "lookup_reads", "leaf_splits",
                  "split_same_ms",
                  "handovers", "hocl_cas", "flat_cas", "cache_hits",
                  "cache_misses", "cache_stale"):
            out[k] = nt[k]          # `phases` = per-CS sum, as pre-PR-5
        for k in ("internal_splits", "root_splits"):
            out[k] = nt[k] + self.counters[k]   # + wave-scope repairs
        return out

    def throughput_mops(self) -> float:
        t = self.counters["sim_time_s"]
        n = self.node_totals()["ops"]
        return n / t / 1e6 if t else 0.0


def build_cluster(features: Features, cfg: TreeConfig, *,
                  n_clients: int, records: int, keyspace: int = 1 << 20,
                  cache_bytes: int = 64 << 20,
                  cache_levels: Optional[int] = None,
                  sync_rounds: int = 4, seed: int = 0,
                  fill: float = 0.8,
                  net: Optional[NetConfig] = None) -> Cluster:
    """Load phase: bulk-load ``records`` scrambled records into the shared
    pool and stand up the CS fleet (mirrors ``workloads.build_index``)."""
    rng = np.random.default_rng(seed)
    keys = scramble(np.arange(records, dtype=np.int64), keyspace)
    vals = rng.integers(0, VAL_MASK, size=records)
    return Cluster.build(cfg, keys, vals, fill=fill, features=features,
                         net=net, n_clients=n_clients,
                         cache_bytes=cache_bytes, cache_levels=cache_levels,
                         sync_rounds=sync_rounds)


def run_cluster(cluster: Cluster, spec: WorkloadSpec, *,
                partitioned: bool = False, seed: int = 1,
                keyspace: int = 1 << 20) -> int:
    """Drive ``spec``'s op mix through the cluster in scheduler rounds.

    Each round hands every CS a ``per_cs``-lane batch from its private
    stream (op mix realized per CS via the salted remainder rotation, so
    even one-lane batches mix over rounds) and executes the waves in a
    fixed kind order (scan, read, rmw, update, delete, insert — the
    engine's order).  Returns ``(done, op_counts)``: the number of client
    ops issued and the realized per-kind mix.
    """
    streams = ClusterStreams(spec, cluster.n_cs, keyspace=keyspace,
                             partitioned=partitioned, seed=seed)
    n_cs, per_cs = cluster.n_cs, cluster.per_cs
    ops_per_round = per_cs * n_cs
    rounds = max(1, -(-spec.ops // ops_per_round))
    done = 0
    op_counts = {k: 0 for k in OP_KINDS}
    for r in range(rounds):
        counts = [spec.batch_counts(per_cs, salt=r * n_cs + cs)
                  for cs in range(n_cs)]

        def gather(kind, draw):
            return [draw(cs, counts[cs][kind]) if counts[cs][kind] else None
                    for cs in range(n_cs)]

        if any(c["scan"] for c in counts):
            cluster.scan_wave(gather("scan", streams.draw),
                              count=spec.scan_len,
                              max_leaves=max(4, spec.scan_len))
        if any(c["read"] for c in counts):
            cluster.lookup_wave(gather("read", streams.draw))
        if any(c["rmw"] for c in counts):
            keys = gather("rmw", streams.draw)
            got = cluster.lookup_wave(keys)
            vals = [((g.astype(np.int64) + 1) & VAL_MASK)
                    if k is not None else None
                    for k, (g, _) in zip(keys, got)]
            cluster.write_wave(keys, vals)
        if any(c["update"] for c in counts):
            keys = gather("update", streams.draw)
            vals = [streams.rngs[cs].integers(0, VAL_MASK, k.size)
                    if k is not None else None
                    for cs, k in enumerate(keys)]
            cluster.write_wave(keys, vals)
        if any(c["delete"] for c in counts):
            cluster.write_wave(gather("delete", streams.draw), None,
                               is_delete=True)
        if any(c["insert"] for c in counts):
            keys = gather("insert", streams.draw_insert)
            vals = [streams.rngs[cs].integers(0, VAL_MASK, k.size)
                    if k is not None else None
                    for cs, k in enumerate(keys)]
            cluster.write_wave(keys, vals)
        cluster.end_round()
        for c in counts:
            for k in OP_KINDS:
                op_counts[k] += c[k]
        done += sum(sum(c.values()) for c in counts)
    return done, {k: v for k, v in op_counts.items() if v}
