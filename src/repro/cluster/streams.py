"""Per-CS key streams for the cluster plane.

Each compute server draws its own operation stream from an independent
RNG, so the fleet's accesses are genuinely uncorrelated — the property
the single-frontend lane-block model could not give.  Two partitioning
policies over the shared record-rank space:

* ``shared`` (default) — every CS draws from the *whole* live-record
  space under the spec's distribution.  Skewed workloads then send every
  CS to the same global hot records: maximal cross-CS contention, the
  paper's §5 evaluation topology.
* ``partitioned`` — DEX-style (arXiv:2405.14502) static sharding: CS *i*
  draws only from its contiguous rank shard, so each CS has a private
  hot set and cross-CS conflicts (and cache-invalidation crosstalk)
  collapse.  The contrast between the two policies is exactly DEX's
  argument that compute-side partitioning, not raw client count,
  dominates scalability.

Inserts use a CS-strided rank cursor (rank ``base + i + k·n_cs`` for
CS *i*) so concurrently inserting CSs never collide on a key; newly
inserted ranks become drawable by every CS in shared mode (YCSB
semantics) and stay out of the static shards in partitioned mode.
"""
from __future__ import annotations

import numpy as np

from repro.workloads.keygen import draw_keys, latest_ranks, scramble, \
    zipf_ranks
from repro.workloads.spec import WorkloadSpec


class ClusterStreams:
    """Per-CS operation/key streams over one shared record space."""

    def __init__(self, spec: WorkloadSpec, n_cs: int, *,
                 keyspace: int, partitioned: bool = False, seed: int = 1):
        self.spec = spec
        self.n_cs = int(n_cs)
        self.keyspace = int(keyspace)
        self.partitioned = bool(partitioned)
        self.rngs = [np.random.default_rng((seed, cs))
                     for cs in range(self.n_cs)]
        self.n_records = int(spec.load_records)   # live records (grows)
        self._insert_base = int(spec.load_records)
        self._inserted = [0] * self.n_cs          # per-CS insert counters
        # static DEX shards over the *loaded* ranks
        per = max(1, spec.load_records // self.n_cs)
        self._shard_lo = [min(cs * per, spec.load_records)
                          for cs in range(self.n_cs)]
        self._shard_len = [max(1, (min((cs + 1) * per, spec.load_records)
                                   - self._shard_lo[cs]))
                           for cs in range(self.n_cs)]

    def draw(self, cs: int, n: int) -> np.ndarray:
        """Draw ``n`` live-record keys for CS ``cs`` (int32)."""
        rng, spec = self.rngs[cs], self.spec
        if not self.partitioned:
            return draw_keys(rng, n, distribution=spec.distribution,
                             theta=spec.theta, nspace=self.n_records,
                             keyspace=self.keyspace).astype(np.int32)
        nspace = self._shard_len[cs]
        if spec.distribution == "uniform":
            ranks = rng.integers(0, nspace, size=n).astype(np.int64)
        elif spec.distribution == "latest":
            ranks = latest_ranks(rng, n, nspace, spec.theta)
        else:
            ranks = zipf_ranks(rng, n, nspace, spec.theta)
        return scramble(self._shard_lo[cs] + ranks,
                        self.keyspace).astype(np.int32)

    def draw_insert(self, cs: int, n: int) -> np.ndarray:
        """Draw ``n`` brand-new record keys for CS ``cs`` (CS-strided
        insertion ranks — concurrent inserters never collide)."""
        k = self._inserted[cs]
        ranks = (self._insert_base + cs
                 + (k + np.arange(n, dtype=np.int64)) * self.n_cs)
        self._inserted[cs] += n
        if not self.partitioned:
            self.n_records = max(self.n_records, int(ranks[-1]) + 1)
        return scramble(ranks, self.keyspace).astype(np.int32)
