"""Per-CS key streams for the cluster plane.

Each compute server draws its own operation stream from an independent
RNG, so the fleet's accesses are genuinely uncorrelated — the property
the single-frontend lane-block model could not give.  Two partitioning
policies over the shared record-rank space:

* ``shared`` (default) — every CS draws from the *whole* live-record
  space under the spec's distribution.  Skewed workloads then send every
  CS to the same global hot records: maximal cross-CS contention, the
  paper's §5 evaluation topology.
* ``partitioned`` — DEX-style (arXiv:2405.14502) static sharding: CS *i*
  draws only from its contiguous rank shard, so each CS has a private
  hot set and cross-CS conflicts (and cache-invalidation crosstalk)
  collapse.  The contrast between the two policies is exactly DEX's
  argument that compute-side partitioning, not raw client count,
  dominates scalability.

Inserts use a CS-strided rank cursor (rank ``base + i + k·n_cs`` for
CS *i*) so concurrently inserting CSs never collide on a key; newly
inserted ranks become drawable by every CS in shared mode (YCSB
semantics) and stay out of the static shards in partitioned mode.
"""
from __future__ import annotations

import numpy as np

from repro.workloads.keygen import draw_keys, hotspot_ranks, latest_ranks, \
    scramble, zipf_ranks
from repro.workloads.spec import WorkloadSpec


class ClusterStreams:
    """Per-CS operation/key streams over one shared record space."""

    def __init__(self, spec: WorkloadSpec, n_cs: int, *,
                 keyspace: int, partitioned: bool = False, seed: int = 1):
        self.spec = spec
        self.n_cs = int(n_cs)
        self.keyspace = int(keyspace)
        self.partitioned = bool(partitioned)
        self.rngs = [np.random.default_rng((seed, cs))
                     for cs in range(self.n_cs)]
        self.n_records = int(spec.load_records)   # live records (grows)
        self._insert_base = int(spec.load_records)
        self._inserted = [0] * self.n_cs          # per-CS insert counters
        # static DEX shards over the *loaded* ranks
        per = max(1, spec.load_records // self.n_cs)
        self._shard_lo = [min(cs * per, spec.load_records)
                          for cs in range(self.n_cs)]
        self._shard_len = [max(1, (min((cs + 1) * per, spec.load_records)
                                   - self._shard_lo[cs]))
                           for cs in range(self.n_cs)]

    def draw(self, cs: int, n: int) -> np.ndarray:
        """Draw ``n`` live-record keys for CS ``cs`` (int32)."""
        rng, spec = self.rngs[cs], self.spec
        if not self.partitioned:
            return draw_keys(rng, n, distribution=spec.distribution,
                             theta=spec.theta, nspace=self.n_records,
                             keyspace=self.keyspace, hot_frac=spec.hot_frac,
                             hot_n=spec.hot_n).astype(np.int32)
        nspace = self._shard_len[cs]
        if spec.distribution == "uniform":
            ranks = rng.integers(0, nspace, size=n).astype(np.int64)
        elif spec.distribution == "latest":
            ranks = latest_ranks(rng, n, nspace, spec.theta)
        elif spec.distribution == "hotspot":
            ranks = hotspot_ranks(rng, n, nspace, spec.hot_frac,
                                  spec.hot_n)
        else:
            ranks = zipf_ranks(rng, n, nspace, spec.theta)
        return scramble(self._shard_lo[cs] + ranks,
                        self.keyspace).astype(np.int32)

    def draw_insert(self, cs: int, n: int) -> np.ndarray:
        """Draw ``n`` brand-new record keys for CS ``cs`` (CS-strided
        insertion ranks — concurrent inserters never collide)."""
        k = self._inserted[cs]
        ranks = (self._insert_base + cs
                 + (k + np.arange(n, dtype=np.int64)) * self.n_cs)
        self._inserted[cs] += n
        if not self.partitioned:
            self.n_records = max(self.n_records, int(ranks[-1]) + 1)
        return scramble(ranks, self.keyspace).astype(np.int32)

    # -- chaos plane: mid-run skew shifts + snapshot -----------------------
    def shift_skew(self, **kw) -> None:
        """Retarget the draw distribution mid-run (the chaos plane's
        skew-shift / hot-key-storm faults).  Only the key *distribution*
        moves; op mix, RNG states and insert cursors are untouched, so
        the op stream stays deterministic across the shift."""
        self.spec = self.spec.replace(**kw)

    def export_state(self) -> dict:
        """JSON-serializable snapshot of the streams' mutable state —
        per-CS RNG states, insert cursors and the (possibly shifted)
        draw-distribution parameters."""
        return dict(
            rng_states=[rng.bit_generator.state for rng in self.rngs],
            inserted=list(self._inserted),
            n_records=self.n_records,
            distribution=self.spec.distribution,
            theta=self.spec.theta,
            hot_frac=self.spec.hot_frac,
            hot_n=self.spec.hot_n,
        )

    def import_state(self, st: dict) -> None:
        """Restore a snapshot taken by :meth:`export_state`."""
        for rng, s in zip(self.rngs, st["rng_states"]):
            rng.bit_generator.state = s
        self._inserted = [int(x) for x in st["inserted"]]
        self.n_records = int(st["n_records"])
        self.spec = self.spec.replace(
            distribution=st["distribution"], theta=st["theta"],
            hot_frac=st["hot_frac"], hot_n=int(st["hot_n"]))
