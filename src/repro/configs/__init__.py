"""Assigned architecture configs (public-literature settings).

``get(name)`` returns the exact assigned :class:`ArchConfig`;
``get_reduced(name)`` returns the CPU-smoke-sized variant of the same
family.  ``ALL_ARCHS`` preserves the assignment order.
"""
from __future__ import annotations

import importlib

ALL_ARCHS = [
    "llama4_scout_17b_a16e",
    "qwen2_moe_a2_7b",
    "command_r_35b",
    "deepseek_67b",
    "smollm_135m",
    "granite_3_8b",
    "rwkv6_1_6b",
    "recurrentgemma_2b",
    "whisper_medium",
    "internvl2_1b",
]

_ALIAS = {a.replace("_", "-"): a for a in ALL_ARCHS}
_ALIAS.update({
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "rwkv6-1.6b": "rwkv6_1_6b",
})


def canon(name: str) -> str:
    return _ALIAS.get(name, name.replace("-", "_").replace(".", "_"))


def get(name: str):
    mod = importlib.import_module(f"repro.configs.{canon(name)}")
    return mod.CONFIG


def get_reduced(name: str):
    mod = importlib.import_module(f"repro.configs.{canon(name)}")
    if hasattr(mod, "REDUCED"):
        return mod.REDUCED
    return mod.CONFIG.reduced()
