"""Cohere Command-R 35B  [hf:CohereForAI/c4ai-command-r-v01].

40L, d_model 8192, 64 heads (GQA kv=8), d_ff 22528, vocab 256000,
no biases, tied embeddings.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab=256000, tie_embeddings=True,
)
