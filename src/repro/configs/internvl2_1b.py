"""InternVL2-1B  [arXiv:2404.16821] — InternViT frontend + Qwen2-0.5B LM.

LM backbone: 24L, d_model 896, 14 heads (GQA kv=2), d_ff 4864,
vocab 151655.  ViT frontend is a stub: input_specs() provides precomputed
patch embeddings [B, 256, 896] prepended to the text sequence.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151655, n_patches=256, tie_embeddings=True,
)
