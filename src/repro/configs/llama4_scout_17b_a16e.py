"""Llama-4 Scout 17B-active / 16-expert  [hf:meta-llama/Llama-4-Scout-17B-16E].

48L, d_model 5120, 40 q-heads (GQA kv=8), d_ff 8192 per expert,
vocab 202048, MoE 16 routed experts top-1 + 1 shared expert (early-fusion
text backbone only; multimodal frontend out of scope for this assignment).
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048,
    n_experts=16, top_k=1, n_shared_experts=1, shared_expert_ff=8192,
    tie_embeddings=False,
)
