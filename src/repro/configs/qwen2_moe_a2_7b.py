"""Qwen1.5-MoE-A2.7B  [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L, d_model 2048, 16 heads (kv=16), 60 routed experts top-4 with
per-expert d_ff 1408, plus 4 shared experts (shared ff 5632), vocab 151936.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=151936,
    n_experts=60, top_k=4, n_shared_experts=4, shared_expert_ff=5632,
    tie_embeddings=False,
)
