"""RecurrentGemma-2B (Griffin)  [arXiv:2402.19427].

26L in (rec, rec, local-attn) super-blocks, d_model 2560, 10 heads
(MQA kv=1), d_ff 7680, vocab 256000, window 2048, lru_width 2560.
Sub-quadratic (bounded window): runs the long_500k cell.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000, window=2048, rg_lru_width=2560,
    conv_width=4, tie_embeddings=True, subquadratic=True,
)
