"""RWKV6 "Finch" 1.6B  [arXiv:2404.05892] — attention-free, data-dependent
decay.  24L, d_model 2048 (32 heads of 64), channel-mix d_ff 7168,
vocab 65536.  Sub-quadratic: runs the long_500k cell.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=7168, vocab=65536, rwkv_head_dim=64,
    tie_embeddings=False, subquadratic=True,
)
