"""SmolLM-135M  [hf:HuggingFaceTB/SmolLM-135M] — small llama-arch.

30L, d_model 576, 9 heads (GQA kv=3), d_ff 1536, vocab 49152, tied.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
    d_ff=1536, vocab=49152, tie_embeddings=True,
)
