"""Whisper-medium backbone  [arXiv:2212.04356] — encoder-decoder.

24 encoder + 24 decoder layers, d_model 1024, 16 heads (kv=16),
d_ff 4096, vocab 51865.  Conv/mel frontend is a stub: input_specs()
provides precomputed frame embeddings [B, 1500, 1024].
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="audio",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865, n_frames=1500, tie_embeddings=True,
)
