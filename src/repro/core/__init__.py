"""Sherman core: a write-optimized distributed B+Tree on a disaggregated
node pool, adapted from RDMA one-sided verbs to batched JAX execution."""
from repro.core.api import (FG_PLUS, SHERMAN, Features, IndexCache,
                            OracleIndex, ShermanIndex, TreeConfig)
from repro.core.tree import TreeState, bulkload

__all__ = ["ShermanIndex", "TreeConfig", "TreeState", "bulkload",
           "Features", "FG_PLUS", "SHERMAN", "OracleIndex", "IndexCache"]
