"""Public API of the Sherman index.

``ShermanIndex`` is the component a database (or a serving stack such as
the paged-KV integration in ``examples/serve_paged.py``) embeds: batched
insert/delete/lookup/range with the paper's full write path, plus per-phase
netsim pricing so every paper metric (throughput, latency percentiles,
doorbell depth, write bytes, retries) falls out of normal use.

Reads route through the functional CS-side index cache
(:mod:`repro.core.cache`): a cache-hit lookup costs one remote leaf read,
a stale hit pays the B-link chase, and a miss retraverses — all three
outcomes are counted (``cache_hits``/``cache_misses``/``cache_stale``) and
priced.

Shape stability (the jit-cache discipline every driver relies on):

* every batch entering a jitted entry point is **padded to a power-of-two
  bucket** (:func:`bucket_size`) with the padding lanes masked inactive,
  so ``_jit_write_phase``/``_jit_lookup``/``_jit_range``/``_jit_repair``
  each compile once per bucket instead of once per batch length;
* the repair queue has a **fixed capacity** (:data:`REPAIR_CAP`)
  independent of the batch size, so repair steps never trigger a
  shape-churn recompile (overflowing separators are dropped, which is
  safe under the B-link invariant — a later traversal rediscovers the
  half-split);
* the tree state (and the repair queue) are **donated** to the jitted
  phases, so XLA updates them in place instead of copying the pool every
  phase.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import netsim, ops, write
from repro.core.cache import IndexCache
from repro.core.netsim import FG_PLUS, SHERMAN, Features, NetConfig
from repro.core.ref import OracleIndex
from repro.core.tree import TreeConfig, TreeState, bulkload, empty_state
from repro.core.write import RepairQueue

__all__ = ["ShermanIndex", "TreeConfig", "Features", "FG_PLUS", "SHERMAN",
           "OracleIndex", "IndexCache", "REPAIR_CAP", "bucket_size",
           "pad_to_bucket"]

#: Fixed capacity of every driver-owned repair queue.  Independent of the
#: batch size so ``_jit_repair``/``_jit_write_phase`` compile once; large
#: enough that one wave's half-splits never overflow in practice (a
#: dropped separator is still safe — B-link rediscovery).
REPAIR_CAP = 256

#: Smallest dispatch bucket; batches below this pad up to it.
BUCKET_MIN = 16


def bucket_size(n: int) -> int:
    """Smallest power-of-two bucket holding ``n`` lanes (>= BUCKET_MIN)."""
    return max(BUCKET_MIN, 1 << max(0, int(n) - 1).bit_length())


def pad_to_bucket(arr: jnp.ndarray, m: int, fill=0) -> jnp.ndarray:
    """Pad a [n, ...] batch array to bucket length ``m`` with ``fill``."""
    n = arr.shape[0]
    if n == m:
        return arr
    pad = jnp.full((m - n,) + arr.shape[1:], fill, arr.dtype)
    return jnp.concatenate([arr, pad])


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1, 7))
def _jit_write_phase(cfg, st, keys, vals, is_delete, active, cs, repair):
    return write.write_phase(cfg, st, keys, vals, is_delete, active, cs,
                             repair)


@functools.partial(jax.jit, static_argnums=(0,))
def _jit_lookup(cfg, st, keys):
    return ops.lookup_batch(cfg, st, keys)


@functools.partial(jax.jit, static_argnums=(0, 3, 4))
def _jit_range(cfg, st, lo, count, max_leaves):
    return ops.range_batch(cfg, st, lo, count, max_leaves)


@functools.partial(jax.jit, static_argnums=(0, 3, 4))
def _jit_range_cached(cfg, st, lo, count, max_leaves, cache_image):
    return ops.range_batch(cfg, st, lo, count, max_leaves, cache_image)


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1, 2))
def _jit_repair(cfg, st, repair):
    """One fixed-shape repair step.  Returns the post-step pending count
    so the drain loop can sync the host every k iterations instead of
    forcing a device round trip per iteration."""
    st, repair, ni, nr = write.run_repair(cfg, st, repair, iters=2)
    pending = jnp.sum(repair.valid.astype(jnp.int32))
    return st, repair, ni, nr, pending


def run_repair_drain(cfg, state, repair, max_iters: int = 16,
                     sync_every: int = 4):
    """Drain a repair queue with k-batched host syncs.

    Runs :func:`_jit_repair` steps back-to-back, accumulating the split
    counters as device scalars, and checks the jitted step's pending
    count on the host only every ``sync_every`` iterations.  Returns
    ``(state, repair, n_internal, n_root, backlog)`` — ``backlog`` is
    the host-side pending count after the drain (0 when it completed).
    Shared by ``ShermanIndex.drain_repairs`` and the cluster scheduler's
    wave-scope drain so their sync semantics cannot diverge.
    """
    ni_acc, nr_acc, pending = [], [], None
    for it in range(max_iters):
        state, repair, ni, nr, pending = _jit_repair(cfg, state, repair)
        ni_acc.append(ni)
        nr_acc.append(nr)
        # one step usually clears a write batch's handful of separators,
        # so check after the first step too, then every sync_every
        if (it == 0 or (it + 1) % sync_every == 0) and not int(pending):
            break
    return (state, repair, sum(int(x) for x in ni_acc),
            sum(int(x) for x in nr_acc), int(pending))


def write_stats_dict(stats: write.WriteStats, active, route_hits,
                     height: int) -> dict:
    """Numpy view of one write phase's per-lane structure — the verb
    plane's input (netsim.price_write_phase / verbs.write_phase_trace).
    Shared with the trace-conservation tests so the two stay in sync."""
    return dict(
        active=np.asarray(active),
        leaf=np.asarray(stats.leaf),
        local_rank=np.asarray(stats.local_rank),
        node_rank=np.asarray(stats.node_rank),
        node_size=np.asarray(stats.node_size),
        cycle_head=np.asarray(stats.cycle_head),
        chain_end=np.asarray(stats.chain_end),
        split_lane=np.asarray(stats.split_mask),
        split_same_ms=np.asarray(stats.split_same_ms),
        split_new_row=np.asarray(stats.split_new_row),
        cache_hit=np.asarray(route_hits),
        height=int(height),
        hocl_remote_cas=int(stats.hocl_remote_cas),
        flat_remote_cas=int(stats.flat_remote_cas),
    )


class ShermanIndex:
    """A write-optimized ordered index over a disaggregated node pool."""

    def __init__(self, cfg: TreeConfig, state: TreeState,
                 features: Features = SHERMAN,
                 net: Optional[NetConfig] = None,
                 cache_bytes: int = 64 << 20,
                 cache_levels: Optional[int] = None,
                 cache_sync_every: int = 8,
                 cache_chase_hops: int = 4,
                 cache_kernel: Optional[str] = None):
        self.cfg = cfg
        self.state = state
        self.features = features
        self.net = net or NetConfig()
        self.cache = IndexCache(cfg, cache_bytes, levels=cache_levels,
                                chase_hops=cache_chase_hops,
                                sync_every=cache_sync_every,
                                kernel_mode=cache_kernel)
        self.counters = {
            "phases": 0, "write_ops": 0, "retried_ops": 0, "read_ops": 0,
            "leaf_splits": 0,
            "internal_splits": 0, "root_splits": 0, "split_same_ms": 0,
            "cas_msgs": 0, "handovers": 0, "msgs": 0, "bytes": 0.0,
            "sim_time_s": 0.0, "cache_hits": 0, "cache_misses": 0,
            "cache_stale": 0, "lookup_ops": 0, "lookup_reads": 0,
            "verbs": 0, "doorbells": 0, "hocl_cas": 0, "flat_cas": 0,
        }
        self.latencies_write: list[np.ndarray] = []
        self.latencies_read: list[np.ndarray] = []
        self.doorbells_write: list[np.ndarray] = []
        self.write_bytes: list[np.ndarray] = []
        self._repair = RepairQueue.empty(REPAIR_CAP)
        self._repair_backlog = 0        # host-side mirror, no device sync
        # opt-in observability plane: attach a repro.obs Recorder here and
        # every priced phase captures its per-verb timeline (DESIGN.md §14)
        self.recorder = None

    # -- constructors --------------------------------------------------
    @classmethod
    def build(cls, cfg: TreeConfig, keys, vals, fill: float = 0.8,
              **kw) -> "ShermanIndex":
        return cls(cfg, bulkload(cfg, keys, vals, fill=fill), **kw)

    @classmethod
    def empty(cls, cfg: TreeConfig, **kw) -> "ShermanIndex":
        return cls(cfg, bulkload(cfg, np.zeros(0), np.zeros(0)), **kw)

    # -- helpers --------------------------------------------------------
    def _cs_of(self, n: int, m: int | None = None) -> jnp.ndarray:
        """Lane -> compute-server assignment (contiguous blocks).

        Block size comes from the *real* batch length ``n`` so the
        distribution over CSs matches the unpadded batch; the returned
        array spans the dispatch bucket ``m`` (padding lanes get a label
        too, but they are inactive everywhere)."""
        per = max(1, -(-n // self.cfg.n_cs))
        return (jnp.arange(m or n, dtype=jnp.int32) // per) % self.cfg.n_cs

    def _rec(self, phase: str):
        """The phase's capture target: label it and place it at the
        accumulated sim time (each closed-loop phase is its own relative
        timeline; the cursor makes the captured segments tile)."""
        r = self.recorder
        if r is not None:
            r.set_phase(phase)
            r.sync_cursor(self.counters["sim_time_s"])
        return r

    def _price_cache_maintenance(self):
        """Charge the image fills / version sweeps the cache performed
        since the last drain by replaying their MAINT/SYNC verbs."""
        node_rd, small_rd = self.cache.take_maintenance()
        if not (node_rd or small_rd):
            return
        sim = netsim.price_maintenance(node_rd, small_rd, self.features,
                                       self.net, self.cfg,
                                       rows_ms=self.cache.rows_ms(),
                                       recorder=self._rec("maint"))
        self._charge(sim)

    def _charge(self, priced: dict):
        """Accumulate one simulated trace's totals into the counters."""
        c = self.counters
        c["msgs"] += priced["msgs"]
        c["verbs"] += priced["verbs"]
        c["doorbells"] += priced["doorbells"]
        c["bytes"] += priced["bytes"]
        c["sim_time_s"] += priced["makespan_s"]

    def _price_write(self, stats: write.WriteStats, active, hits):
        sd = write_stats_dict(stats, active, hits, int(self.state.height))
        priced = netsim.price_write_phase(sd, self.features, self.net,
                                          self.cfg,
                                          recorder=self._rec("write"))
        self.latencies_write.append(priced["latency_s"])
        self.doorbells_write.append(priced["lane_doorbells"])
        self.write_bytes.append(priced["write_bytes"])
        self._charge(priced)
        c = self.counters
        c["phases"] += 1
        c["cas_msgs"] += priced["cas_msgs"]
        c["hocl_cas"] += sd["hocl_remote_cas"]
        c["flat_cas"] += sd["flat_remote_cas"]
        c["leaf_splits"] += int(stats.n_leaf_splits)
        c["internal_splits"] += int(stats.n_internal_splits)
        c["root_splits"] += int(stats.n_root_splits)
        c["split_same_ms"] += int(stats.n_split_same_ms)
        c["handovers"] += int(stats.handovers)

    # -- write ops -------------------------------------------------------
    def _write(self, keys, vals, is_delete, max_phases: int = 8):
        keys = jnp.asarray(keys, jnp.int32)
        n = keys.shape[0]
        if n == 0:
            return
        m = bucket_size(n)
        vals = jnp.asarray(vals, jnp.int32) if vals is not None else \
            jnp.zeros((n,), jnp.int32)
        keys = pad_to_bucket(keys, m)
        vals = pad_to_bucket(vals, m)
        is_del = jnp.broadcast_to(jnp.asarray(is_delete, bool), (m,))
        cs = self._cs_of(n, m)
        active = jnp.arange(m) < n           # padding lanes stay inactive
        # the writes' traversal leg routes through the CS cache like a read;
        # probe once per batch (retry phases reuse the same routing)
        if self.cache.enabled:
            route_hits = self.cache.route_hits(self.state, keys, n_valid=n)
        else:
            route_hits = np.zeros(m, bool)
        # each client op counts once; lanes resubmitted by later phases
        # are tracked separately so throughput isn't inflated
        self.counters["write_ops"] += n
        for phase_no in range(max_phases):
            self.state, done, stats, self._repair = _jit_write_phase(
                self.cfg, self.state, keys, vals, is_del, active, cs,
                self._repair)
            self._price_write(stats, np.asarray(active), route_hits)
            if phase_no:
                self.counters["retried_ops"] += int(np.asarray(active).sum())
            # invalidation hook: feed this phase's split outputs to the cache
            self.cache.note_splits(int(stats.n_leaf_splits),
                                   int(stats.n_internal_splits),
                                   int(stats.n_root_splits), self.state)
            self._repair_backlog = int(stats.repair_backlog)
            active = active & ~done
            if not bool(jnp.any(active)):
                break
        if bool(jnp.any(active)):
            raise RuntimeError("write batch did not converge; "
                               "pool exhausted or max_phases too low")
        self.drain_repairs()
        self._price_cache_maintenance()

    def drain_repairs(self, max_iters: int = 16, sync_every: int = 4):
        """Complete any outstanding B-link half-splits.

        The jitted repair step returns the post-step pending count, so
        the loop touches the host only every ``sync_every`` iterations
        (and not at all when the last write phase reported an empty
        queue) instead of forcing a device sync per iteration.
        """
        if not self._repair_backlog:
            return
        (self.state, self._repair, n_int, n_root,
         self._repair_backlog) = run_repair_drain(
            self.cfg, self.state, self._repair, max_iters, sync_every)
        self.counters["internal_splits"] += n_int
        self.counters["root_splits"] += n_root
        if n_int or n_root:
            self.cache.note_splits(0, n_int, n_root, self.state)
        if self._repair_backlog:
            raise RuntimeError("repair queue did not drain")

    def insert(self, keys, vals):
        """Insert or update (the paper's combined 'insert')."""
        self._write(keys, vals, False)

    def delete(self, keys):
        self._write(keys, None, True)

    # -- read ops ----------------------------------------------------------
    def lookup(self, keys):
        keys = jnp.asarray(keys, jnp.int32)
        n = keys.shape[0]
        m = bucket_size(n)
        kp = pad_to_bucket(keys, m)
        c = self.counters
        active = np.arange(m) < n
        if self.cache.enabled:
            res, cst = self.cache.lookup(self.state, kp, n_valid=n)
            hit, stale = cst["hit"][:n], cst["stale"][:n]
            c["cache_hits"] += int((hit & ~stale).sum())
            c["cache_misses"] += int((~hit).sum())
            c["cache_stale"] += int(stale.sum())
            sd = dict(active=active,
                      cache_hit=cst["hit"] & ~cst["stale"],
                      remote_reads=cst["remote_reads"],
                      leaf=np.asarray(res.leaf),
                      height=int(self.state.height))
        else:
            res = _jit_lookup(self.cfg, self.state, kp)
            c["cache_misses"] += n
            sd = dict(active=active,
                      cache_hit=np.zeros(m, bool),
                      leaf=np.asarray(res.leaf),
                      height=int(self.state.height))
        priced = netsim.price_read_phase(sd, self.features, self.net,
                                         self.cfg,
                                         recorder=self._rec("read"))
        self.latencies_read.append(priced["latency_s"])
        c["read_ops"] += n
        c["lookup_ops"] += n
        c["lookup_reads"] += int(np.asarray(priced["lane_doorbells"]).sum())
        self._charge(priced)
        self._price_cache_maintenance()
        return np.asarray(res.value)[:n], np.asarray(res.found)[:n]

    def range(self, lo, count: int, max_leaves: Optional[int] = None):
        lo = jnp.asarray(lo, jnp.int32)
        n = lo.shape[0]
        m = bucket_size(n)
        lo_p = pad_to_bucket(lo, m)
        if max_leaves is None:
            # Leaves may be sparse (deletes don't merge — §5.3 notes the same
            # partial-occupancy artifact), so scan generously.
            max_leaves = max(4, count)
        # the scan's initial descent consults the CS cache like a lookup
        if self.cache.enabled:
            res = _jit_range_cached(self.cfg, self.state, lo_p, count,
                                    max_leaves,
                                    self.cache.image(self.state))
            hits = np.asarray(res.start_hit)
            self.cache.note_hits(hits[:n])
        else:
            res = _jit_range(self.cfg, self.state, lo_p, count, max_leaves)
            hits = np.zeros(m, bool)
        n_leaves = np.asarray(res.leaves_read)
        priced = netsim.price_read_phase(
            dict(active=np.arange(m) < n, cache_hit=hits,
                 retries=np.maximum(n_leaves - 1, 0),  # empty scans read 0
                 leaf=np.asarray(res.start_leaf), scan=True,
                 height=int(self.state.height)),
            self.features, self.net, self.cfg,
            recorder=self._rec("scan"))
        self.latencies_read.append(priced["latency_s"])
        self.counters["read_ops"] += n
        self._charge(priced)
        self._price_cache_maintenance()
        return (np.asarray(res.keys)[:n], np.asarray(res.vals)[:n],
                np.asarray(res.n)[:n])

    # -- reporting ---------------------------------------------------------
    def latency_percentiles(self, kind: str = "write"):
        arrs = self.latencies_write if kind == "write" else \
            self.latencies_read
        if not arrs:
            return {}
        lat = np.concatenate(arrs)
        return {p: float(np.percentile(lat, p)) * 1e6
                for p in (50, 90, 99)}   # µs

    def throughput_mops(self) -> float:
        """Ops per simulated second.  0.0 before any op has been priced —
        never ``inf``, which would leak non-standard ``Infinity`` tokens
        into the BENCH json exports."""
        t = self.counters["sim_time_s"]
        n = self.counters["write_ops"] + self.counters["read_ops"]
        return n / t / 1e6 if t else 0.0
