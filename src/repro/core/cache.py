"""CS-side index cache (paper §4.2.3): a functional replicated image of the
internal tree levels, with versioned invalidation.

Each compute server keeps an in-memory image of the internal B+Tree levels
(keys + child pointers + the node version observed at fill time) so that a
lookup descends *locally* and issues exactly **one** remote leaf read on a
cache hit.  The remote read is validated by the two-level version protocol
(FNV/RNV + entry versions, paper Fig. 9) and by the leaf's fence keys; a
stale cache entry — e.g. a leaf that split after the image was taken — is
recovered by the B-link sibling chase, falling back to a full root-to-leaf
retraversal when the chase budget is exhausted (paper §4.2.1/§4.2.3).

Coherence protocol (documented in docs/DESIGN.md §9):

1. **Fill/refresh** — snapshot all internal nodes top-down within the byte
   budget (top levels always cached; level-1 nodes evicted first when the
   budget is short), recording each node's FNV.
2. **Validate-on-read** — every cached descent ends in one remote leaf read
   checked with FNV/RNV, the free bit, the level, and the fence keys.
3. **Stale traversal** — a fence miss triggers the sibling chase
   (``chase_hops`` bound) and then a root retraversal; the detection lazily
   invalidates the covering cached entry, exactly like the paper's CS-side
   invalidation.
4. **Version sync** — split outputs from :mod:`repro.core.write` drive a
   periodic sweep that re-reads the FNVs of all cached rows and invalidates
   entries whose version moved (a root split forces a full refresh).

The descent and the leaf probe are shape-static JAX; the hot leaf search
runs through the Pallas kernel in :mod:`repro.kernels.leaf_search.kernel`
(``interpret`` mode off-TPU, with :mod:`repro.kernels.leaf_search.ref` as
the pure-jnp fallback).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.ops import LookupResult, traverse
from repro.core.tree import EMPTY_KEY, NULL_PTR, TreeConfig, TreeState

ROW_SENTINEL = np.int32(2**31 - 1)     # "no row" padding in the sorted image


class CacheStats(NamedTuple):
    """Per-lane cache outcome of one batched cached lookup."""
    hit: jax.Array           # [B] bool — descent resolved inside the cache
    stale: jax.Array         # [B] bool — hit, but the leaf image was stale
    remote_reads: jax.Array  # [B] int32 — node reads a real CS would issue


# --------------------------------------------------------------------------
# image construction (host side)
# --------------------------------------------------------------------------

def fill_image(cfg: TreeConfig, st: TreeState, levels: Optional[int] = None,
               max_rows: Optional[int] = None) -> tuple[dict, int]:
    """Snapshot the top ``levels`` internal levels into a replicated image.

    Returns ``(image, evicted)``: a dict of jnp arrays (a pytree, so it
    passes through jit and shard_map) and the number of nodes dropped for
    the row budget.  The image holds sorted global ``rows`` (padded with
    ``ROW_SENTINEL``), their
    ``keys``/``vals``/``level``, a ``valid`` mask, the ``fnv`` observed at
    fill time, and the ``root``.  Rows are chosen top-down so the upper
    levels are always cached and level-1 nodes are the first evicted when
    ``max_rows`` is short (paper §4.2.3's two cache types).
    """
    height = int(st.height)
    if levels is None:
        levels = max(0, height - 1)          # every internal level
    level = np.asarray(st.level)
    free = np.asarray(st.free_bit)
    lo_level = max(1, height - levels)
    cand = np.nonzero((level >= lo_level) & ~free)[0].astype(np.int32)
    # top-down: higher levels first, row order within a level
    order = np.lexsort((cand, -level[cand].astype(np.int64)))
    cand = cand[order]
    if max_rows is None:
        max_rows = max(1, cand.shape[0])
    kept = np.sort(cand[:max_rows])
    evicted = max(0, cand.shape[0] - max_rows)
    pad = max_rows - kept.shape[0]
    rows = np.concatenate([kept, np.full(pad, ROW_SENTINEL, np.int32)])
    safe = np.clip(rows, 0, cfg.n_nodes - 1)
    img = dict(
        rows=jnp.asarray(rows),
        keys=jnp.asarray(np.asarray(st.keys)[safe]),
        vals=jnp.asarray(np.asarray(st.vals)[safe]),
        level=jnp.asarray(np.asarray(st.level)[safe]),
        valid=jnp.asarray(rows != ROW_SENTINEL),
        fnv=jnp.asarray(np.asarray(st.fnv)[safe]),
        root=jnp.asarray(st.root),
    )
    return img, evicted


# --------------------------------------------------------------------------
# cached descent + validated lookup (pure JAX, shape-static)
# --------------------------------------------------------------------------

def descend_image(image: dict, qkeys: jax.Array, max_steps: int
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Route ``qkeys`` through the cached internal levels.

    Returns ``(target, hit, depth)``: for hit lanes (descent stayed inside
    cached+valid nodes down to a level-1 node) ``target`` is the predicted
    leaf; for miss lanes it is the *frontier* — the first uncached node on
    the path (the root when even the root image is gone) — from which a
    real CS resumes its remote descent.  ``depth`` counts the cached
    descents, so a miss is priced as the remaining ``height - depth``
    remote reads.
    """
    crows, cvalid = image["rows"], image["valid"]
    ckeys, cvals, clevel = image["keys"], image["vals"], image["level"]
    b = qkeys.shape[0]
    node = jnp.broadcast_to(image["root"], (b,)).astype(jnp.int32)
    leaf = jnp.zeros((b,), jnp.int32)
    done = jnp.zeros((b,), bool)
    dead = jnp.zeros((b,), bool)
    depth = jnp.zeros((b,), jnp.int32)
    for _ in range(max_steps):
        pos = jnp.clip(jnp.searchsorted(crows, node), 0,
                       crows.shape[0] - 1)
        ok = (crows[pos] == node) & cvalid[pos]
        lv = clevel[pos].astype(jnp.int32)
        nk = ckeys[pos]
        nv = cvals[pos]
        occupied = nk != EMPTY_KEY
        le = occupied & (nk <= qkeys[:, None])
        j = jnp.maximum(jnp.sum(le.astype(jnp.int32), axis=1) - 1, 0)
        child = jnp.take_along_axis(nv, j[:, None], axis=1)[:, 0]
        live = ~done & ~dead
        reach = live & ok & (lv == 1) & (child != NULL_PTR)
        leaf = jnp.where(reach, child, leaf)
        done = done | reach
        dead = dead | (live & (~ok | (ok & (lv <= 0))))
        step = live & ok & (lv >= 1)
        depth = depth + step.astype(jnp.int32)
        node = jnp.where(live & ok & (lv > 1), child, node)
    return jnp.where(done, leaf, node), done, depth


def _leaf_probe(st: TreeState, leaf: jax.Array, qkeys: jax.Array,
                kernel_mode: str) -> LookupResult:
    """Search the fetched leaf images: Pallas kernel or jnp reference.

    ``kernel_mode``: ``"pallas"`` (compiled, TPU), ``"interpret"``
    (Pallas interpreter — used by CPU tests for kernel parity), ``"ref"``
    (the pure-jnp oracle from :mod:`repro.kernels.leaf_search.ref`).
    """
    args = (qkeys, st.keys[leaf], st.vals[leaf], st.fev[leaf], st.rev[leaf],
            st.fnv[leaf].astype(jnp.int32), st.rnv[leaf].astype(jnp.int32),
            st.free_bit[leaf].astype(jnp.int32))
    if kernel_mode == "ref" or qkeys.shape[0] == 0:  # kernel needs a tile
        from repro.kernels.leaf_search.ref import leaf_search_ref
        value, found, cons = leaf_search_ref(*args)
    else:
        from repro.kernels.leaf_search.kernel import leaf_search
        b = qkeys.shape[0]
        bt = 256
        padded = -(-b // bt) * bt if b > bt else b
        if padded != b:                      # pad to the kernel tile
            pad = padded - b
            # pad lanes: query key -2 against all-zero images => no match
            args = tuple(jnp.concatenate(
                [a, jnp.full((pad,) + a.shape[1:], -2 if i == 0 else 0,
                             a.dtype)])
                for i, a in enumerate(args))
        value, found, cons = leaf_search(
            *args, bt=min(bt, padded),
            interpret=(kernel_mode == "interpret"))
        value, found, cons = value[:b], found[:b], cons[:b]
    return LookupResult(value=value, found=found, consistent=cons,
                        leaf=leaf, hops=jnp.zeros_like(leaf))


def leaf_sound(st: TreeState, leaf: jax.Array, keys: jax.Array) -> jax.Array:
    """Is the fetched node a live leaf whose fence range covers ``keys``?
    The shared validation for every cached descent (lookups and scans)."""
    return (st.level[leaf].astype(jnp.int32) == 0) & ~st.free_bit[leaf] & \
        (st.fence_lo[leaf] <= keys) & (keys < st.fence_hi[leaf])


def cached_lookup(cfg: TreeConfig, st: TreeState, image: dict,
                  qkeys: jax.Array, chase_hops: int = 4,
                  kernel_mode: str = "ref"
                  ) -> tuple[LookupResult, CacheStats]:
    """One batched lookup through the cache: local descent, one remote leaf
    read on a hit, B-link chase + root retraversal on staleness.

    Functionally everything is computed full-width (phase-synchronous SIMD);
    ``CacheStats.remote_reads`` counts what a real CS would have issued, and
    is what netsim prices.
    """
    leaf0, hit, depth = descend_image(image, qkeys, cfg.max_height)
    leaf = jnp.where(hit, leaf0, 0)

    # --- the single remote leaf read, validated by fences + B-link chase ---
    chased = jnp.zeros_like(leaf)
    for _ in range(chase_hops):
        beyond = hit & (qkeys >= st.fence_hi[leaf]) & \
            (st.sibling[leaf] != NULL_PTR)
        chased = chased + beyond.astype(jnp.int32)
        leaf = jnp.where(beyond, st.sibling[leaf], leaf)
    sound = hit & leaf_sound(st, leaf, qkeys)

    # --- fallback: full root-to-leaf retraversal for miss/unrecovered
    # lanes; skipped entirely when the whole batch hit (the warm case) ---
    final = lax.cond(
        jnp.all(sound),
        lambda: leaf,
        lambda: jnp.where(sound, leaf, traverse(cfg, st, qkeys).leaf))
    res = _leaf_probe(st, final, qkeys, kernel_mode)

    height = st.height.astype(jnp.int32)
    stale = hit & ((chased > 0) | ~sound)
    # a partial descent resumes remotely from the first uncached level
    miss_reads = jnp.maximum(height - depth, 1)
    reads = jnp.where(sound, 1 + chased,
                      jnp.where(hit, 1 + chased + height, miss_reads))
    return (res._replace(hops=reads),
            CacheStats(hit=hit, stale=stale, remote_reads=reads))


@functools.partial(jax.jit, static_argnums=(0, 4, 5))
def _jit_cached_lookup(cfg, st, image, qkeys, chase_hops, kernel_mode):
    return cached_lookup(cfg, st, image, qkeys, chase_hops, kernel_mode)


@functools.partial(jax.jit, static_argnums=(2,))
def _jit_route(image, qkeys, max_steps):
    return descend_image(image, qkeys, max_steps)


def default_kernel_mode() -> str:
    """Pallas on TPU; the jnp reference oracle elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "ref"


# --------------------------------------------------------------------------
# the stateful per-CS cache subsystem
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CacheCounters:
    hits: int = 0            # descent resolved in-cache, leaf read clean
    misses: int = 0          # descent left the cached/valid set
    stale: int = 0           # hit but the leaf image was stale (chase/retrav)
    evictions: int = 0       # nodes dropped at fill for the byte budget
    invalidations: int = 0   # entries invalidated (lazy + version sync)
    fills: int = 0           # full image (re)fills
    sync_sweeps: int = 0     # version-sync sweeps over the cached rows
    remote_reads: int = 0    # leaf/node reads issued by cached lookups
    fill_reads: int = 0      # whole-node reads spent (re)filling the image
    sync_reads: int = 0      # small version reads spent on sync sweeps

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class IndexCache:
    """The per-CS cache: replicated image + counters + coherence policy.

    The single-frontend ``ShermanIndex`` holds one instance standing in
    for every CS's identical replica (modeled footprint is
    ``capacity_bytes`` *per CS*); in the cluster plane each
    ``ClusterNode`` owns its **own** instance with its own staleness
    trajectory (DESIGN.md §11).  ``sync_every`` is the number of
    split-bearing write phases between version sweeps; ``sync_rounds``
    adds a scheduler-round-periodic sweep (see :meth:`end_round`); a
    root split always forces a refresh on the next read.
    """

    def __init__(self, cfg: TreeConfig, capacity_bytes: int = 64 << 20,
                 levels: Optional[int] = None, chase_hops: int = 4,
                 sync_every: int = 8, refresh_frac: float = 0.125,
                 sync_rounds: int = 0,
                 kernel_mode: Optional[str] = None):
        self.cfg = cfg
        self.capacity_bytes = int(capacity_bytes)
        self.capacity_rows = max(1, min(
            self.capacity_bytes // max(cfg.node_bytes, 1), cfg.n_nodes))
        self.levels = levels
        self.chase_hops = int(chase_hops)
        self.sync_every = int(sync_every)
        self.sync_rounds = int(sync_rounds)
        self.refresh_frac = float(refresh_frac)
        self.kernel_mode = kernel_mode or default_kernel_mode()
        self.counters = CacheCounters()
        self._rounds_since_sync = 0
        self._image: Optional[dict] = None
        self._rows = np.zeros(0, np.int32)       # host copy of cached rows
        self._filled = np.zeros(0, bool)
        self._valid = np.zeros(0, bool)
        self._fnv = np.zeros(0, np.uint8)
        self._root = -1
        self._splitty_phases = 0
        self._needs_refresh = True
        self._maint_taken = (0, 0)      # (fill_reads, sync_reads) drained

    # -- image lifecycle ---------------------------------------------------
    @property
    def enabled(self) -> bool:
        return self.capacity_bytes > 0

    @property
    def cached_bytes(self) -> int:
        return int(self._valid.sum()) * self.cfg.node_bytes

    def fill(self, st: TreeState) -> None:
        """(Re)build the image from the current tree state."""
        self._image, evicted = fill_image(
            self.cfg, st, levels=self.levels, max_rows=self.capacity_rows)
        self._rows = np.asarray(self._image["rows"])
        self._filled = self._rows != ROW_SENTINEL
        self._valid = np.asarray(self._image["valid"]).copy()
        self._fnv = np.asarray(self._image["fnv"]).copy()
        self._root = int(st.root)
        self.counters.evictions += evicted
        self.counters.fills += 1
        self.counters.fill_reads += int(self._filled.sum())
        self._splitty_phases = 0
        self._needs_refresh = False

    def image(self, st: TreeState) -> dict:
        if self._image is None or self._needs_refresh or \
                int(st.root) != self._root or self._stale_frac() > \
                self.refresh_frac:
            self.fill(st)
        return self._image

    def _stale_frac(self) -> float:
        n = int(self._filled.sum())
        return (int((self._filled & ~self._valid).sum()) / n) if n else 0.0

    def _set_valid(self, valid: np.ndarray) -> None:
        self._valid = valid
        self._image = dict(self._image, valid=jnp.asarray(valid))
        # an invalid upper-level (or root) row cuts off descent for a huge
        # key range — far more than its 1/rows share of _stale_frac — so
        # losing one forces a refresh rather than waiting on the threshold
        bad = self._filled & ~valid
        if bad.any():
            lv = np.asarray(self._image["level"])
            if (lv[bad] > 1).any() or bad[self._rows == self._root].any():
                self._needs_refresh = True

    # -- invalidation ------------------------------------------------------
    def invalidate_covering(self, keys: np.ndarray) -> int:
        """Lazy invalidation: drop the level-1 entries routing ``keys``
        (the paper's invalidate-on-stale-detection)."""
        if self._image is None or keys.size == 0:
            return 0
        lo = np.asarray(self._image["keys"])[:, 0]   # first separator = lo
        lv = np.asarray(self._image["level"])
        # covering is keyed over ALL filled level-1 entries (valid or
        # already dropped): the entry with max lo <= k covers k
        cand = np.nonzero(self._filled & (lv == 1))[0]
        if cand.size == 0:
            return 0
        order = np.argsort(lo[cand], kind="stable")
        cand = cand[order]
        pos = np.searchsorted(lo[cand], np.unique(keys), side="right") - 1
        cover = np.unique(cand[pos[pos >= 0]])
        hit = cover[self._valid[cover]]
        if hit.size:
            valid = self._valid.copy()
            valid[hit] = False
            self._set_valid(valid)
            self.counters.invalidations += int(hit.size)
        return int(hit.size)

    def sync_versions(self, st: TreeState) -> int:
        """Versioned invalidation: re-read the FNV of every cached row and
        invalidate entries whose version moved since fill.  The sweep's
        wire cost accrues in ``counters.sync_reads`` (one small read per
        cached row) and is drained into netsim by the API's
        ``take_maintenance`` pricing."""
        if self._image is None:
            return 0
        safe = np.clip(self._rows, 0, self.cfg.n_nodes - 1)
        now = np.asarray(st.fnv)[safe]
        freed = np.asarray(st.free_bit)[safe]
        changed = self._valid & ((now != self._fnv) | freed)
        n = int(changed.sum())
        if n:
            self._set_valid(self._valid & ~changed)
            self.counters.invalidations += n
        self.counters.sync_sweeps += 1
        self.counters.sync_reads += int(self._filled.sum())
        self._splitty_phases = 0
        return n

    def end_round(self, st: TreeState) -> None:
        """Cluster-plane coherence tick: one scheduler round elapsed.

        In the multi-CS plane a compute server is *not* fed remote CSs'
        split outputs (``note_splits`` fires only for its own writes); it
        learns of remote structural changes lazily — stale detection on
        its own reads — or through this periodic sweep, one version sync
        every ``sync_rounds`` rounds (0 disables).  The sweep's wire cost
        accrues like any other sync (``counters.sync_reads``) and is
        drained by ``take_maintenance``.
        """
        if not (self.enabled and self.sync_rounds and
                self._image is not None):
            return
        self._rounds_since_sync += 1
        if self._rounds_since_sync >= self.sync_rounds:
            self._rounds_since_sync = 0
            self.sync_versions(st)

    def note_splits(self, n_leaf: int, n_internal: int, n_root: int,
                    st: TreeState) -> None:
        """Invalidation hook: called by the API with the split outputs of
        one write batch (:class:`repro.core.write.WriteStats`)."""
        if not self.enabled or self._image is None:
            return
        if n_root:
            self._needs_refresh = True
            return
        if n_leaf or n_internal:
            self._splitty_phases += 1
            if self.sync_every and self._splitty_phases >= self.sync_every:
                self.sync_versions(st)

    # -- lookups -----------------------------------------------------------
    def lookup(self, st: TreeState, qkeys: jax.Array,
               n_valid: Optional[int] = None) -> tuple[LookupResult, dict]:
        """Batched cached lookup; returns the result plus numpy stats
        (``hit``/``stale``/``remote_reads`` per lane) for netsim.

        ``n_valid`` marks the real batch length when the caller padded
        ``qkeys`` to a dispatch bucket (:func:`repro.core.api.bucket_size`)
        — the returned arrays stay full width, but only the first
        ``n_valid`` lanes touch the counters and the lazy invalidation.
        """
        img = self.image(st)
        res, cst = _jit_cached_lookup(self.cfg, st, img, qkeys,
                                      self.chase_hops, self.kernel_mode)
        hit = np.asarray(cst.hit)
        stale = np.asarray(cst.stale)
        reads = np.asarray(cst.remote_reads)
        k = hit.shape[0] if n_valid is None else int(n_valid)
        self.counters.hits += int((hit[:k] & ~stale[:k]).sum())
        self.counters.misses += int((~hit[:k]).sum())
        self.counters.stale += int(stale[:k].sum())
        self.counters.remote_reads += int(reads[:k].sum())
        if stale[:k].any():                  # lazy invalidation on detection
            self.invalidate_covering(np.asarray(qkeys)[:k][stale[:k]])
        return res, dict(hit=hit, stale=stale, remote_reads=reads)

    def route_hits(self, st: TreeState, qkeys: jax.Array,
                   n_valid: Optional[int] = None) -> np.ndarray:
        """Descent-only hit mask (no state mutation of the counters' stale
        plane) — used to price the traversal leg of write ops.  With
        ``n_valid``, padding lanes beyond it stay out of the counters."""
        if not self.enabled:
            return np.zeros(np.asarray(qkeys).shape[0], bool)
        img = self.image(st)
        _, hit, _ = _jit_route(img, qkeys, self.cfg.max_height)
        hit = np.asarray(hit)
        self.note_hits(hit if n_valid is None else hit[:int(n_valid)])
        return hit

    def note_hits(self, hit: np.ndarray) -> None:
        """Count descent-only hit/miss outcomes (write routing, scans)."""
        hit = np.asarray(hit)
        self.counters.hits += int(hit.sum())
        self.counters.misses += int((~hit).sum())

    def take_maintenance(self) -> tuple[int, int]:
        """Drain the un-priced maintenance traffic since the last call:
        ``(node_reads, small_reads)`` for image fills and version sweeps.
        The API replays these as MAINT/SYNC verbs through netsim."""
        f0, s0 = self._maint_taken
        f1, s1 = self.counters.fill_reads, self.counters.sync_reads
        self._maint_taken = (f1, s1)
        return f1 - f0, s1 - s0

    def rows_ms(self) -> np.ndarray:
        """Owning MS of every filled cache row — the verb plane spreads
        maintenance reads over these instead of a blind round-robin."""
        if self._image is None:
            return np.zeros(0, np.int32)
        return self.cfg.ms_of(self._rows[self._filled]).astype(np.int32)

    # -- chaos plane: cold restart + full-state snapshot -------------------
    def reset(self) -> None:
        """Cold restart: drop the image (a CS that just joined the fleet
        has nothing cached — its first read triggers a full fill, the
        warm-up transient the chaos plane prices; DESIGN.md §13).
        Cumulative counters are kept: they are this CS's *history*, and
        the cluster conservation invariant sums them across the run."""
        self._image = None
        self._rows = np.zeros(0, np.int32)
        self._filled = np.zeros(0, bool)
        self._valid = np.zeros(0, bool)
        self._fnv = np.zeros(0, np.uint8)
        self._root = -1
        self._splitty_phases = 0
        self._rounds_since_sync = 0
        self._needs_refresh = True

    def export_state(self) -> tuple[Optional[dict], dict]:
        """Snapshot the cache's full mutable state as
        ``(image_arrays, scalars)`` — everything a tick-for-tick resume
        needs (the image drives routing and maintenance pricing, so a
        resumed run with a refilled-instead-of-restored cache would
        diverge from the uninterrupted one)."""
        image = None
        if self._image is not None:
            image = {k: np.asarray(v) for k, v in self._image.items()}
        scalars = dict(
            counters=self.counters.as_dict(),
            rounds_since_sync=self._rounds_since_sync,
            splitty_phases=self._splitty_phases,
            needs_refresh=self._needs_refresh,
            maint_taken=list(self._maint_taken),
        )
        return image, scalars

    def import_state(self, image: Optional[dict], scalars: dict) -> None:
        """Restore a snapshot taken by :meth:`export_state`."""
        if image is None:
            self.reset()
        else:
            self._image = {k: jnp.asarray(v) for k, v in image.items()}
            self._rows = np.asarray(image["rows"])
            self._filled = self._rows != ROW_SENTINEL
            self._valid = np.asarray(image["valid"]).copy()
            self._fnv = np.asarray(image["fnv"]).copy()
            self._root = int(image["root"])
        self.counters = CacheCounters(**scalars["counters"])
        self._rounds_since_sync = int(scalars["rounds_since_sync"])
        self._splitty_phases = int(scalars["splitty_phases"])
        self._needs_refresh = bool(scalars["needs_refresh"])
        self._maint_taken = tuple(scalars["maint_taken"])

    # -- reporting ---------------------------------------------------------
    @property
    def hit_ratio(self) -> float:
        c = self.counters
        t = c.hits + c.misses + c.stale
        return c.hits / t if t else 1.0
