"""HOCL — hierarchical on-chip lock, adapted to batched SIMD execution.

The paper's lock hierarchy (§4.3):

* GLT  — per-MS lock array in NIC on-chip SRAM, acquired with 16-bit masked
  RDMA_CAS, released with RDMA_WRITE.
* LLT  — per-CS local lock table with FIFO wait queues; threads of one CS
  queue locally instead of spamming remote CAS, and a released lock is
  *handed over* to the next local waiter (≤ MAX_DEPTH = 4 consecutive
  handovers) saving the remote acquisition round trip.

SIMD adaptation (DESIGN.md §2/§8): a batch lane ≡ a client thread; lanes are
grouped by (compute server, target node).  A local group of size k is exactly
a local wait queue of depth k: its ops are applied FIFO by one representative
and cost ``ceil(k / (MAX_DEPTH+1))`` remote lock cycles — the first acquire
plus one fresh acquire each time the handover chain hits the depth cap.
Cross-CS contention on a node serializes the per-CS groups; the serialization
*rank* of each group feeds the netsim queueing model (failed-CAS retries for
the no-HOCL baseline, queue depth for tail latency).

Everything here is pure shape-static JAX so it runs inside the jitted write
phase and under shard_map.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.tree import TreeConfig


class Groups(NamedTuple):
    """Conflict-group decomposition of a batch of node-targeted ops.

    All arrays are in *lane* order unless suffixed ``_sorted``.
    """
    perm: jax.Array              # [B] lanes sorted by (node, cs, lane)
    inv: jax.Array               # [B] inverse permutation
    local_rank: jax.Array        # [B] FIFO rank inside the (cs, node) group
    local_size: jax.Array        # [B] size of own (cs, node) group
    local_head: jax.Array        # [B] bool — first lane of local group
    cycle_head: jax.Array        # [B] bool — starts a handover cycle, i.e.
                                 #    issues the remote LOCK CAS (verb plane)
    chain_end: jax.Array         # [B] bool — ends a handover chain, i.e.
                                 #    issues the remote UNLOCK (verb plane)
    node_rank: jax.Array         # [B] rank inside the node group
    node_size: jax.Array         # [B] size of own node group
    node_head: jax.Array         # [B] bool — first lane of node group
    cs_rank: jax.Array           # [B] serialization rank of own CS's group
    n_cs_on_node: jax.Array      # [B] #distinct CSs contending for the node
    lock_cycles: jax.Array       # [B] remote lock acquisitions by own group
    n_node_groups: jax.Array     # [] distinct nodes targeted
    n_local_groups: jax.Array    # [] distinct (cs, node) pairs


def _ids_from_flags(flags: jax.Array) -> jax.Array:
    """Group ids (0-based) from per-position new-group flags, sorted order."""
    return jnp.cumsum(flags.astype(jnp.int32)) - 1


def _segment_stat(values, seg_ids, num_segments, combine="sum"):
    fn = {"sum": jax.ops.segment_sum, "min": jax.ops.segment_min,
          "max": jax.ops.segment_max}[combine]
    return fn(values, seg_ids, num_segments=num_segments)


def group_by_node(cfg: TreeConfig, node: jax.Array, cs: jax.Array,
                  active: jax.Array) -> Groups:
    """Decompose a batch into HOCL conflict groups.

    Inactive lanes are parked on a sentinel node id so they never collide
    with real groups (and are excluded from all counters).
    """
    b = node.shape[0]
    lane = jnp.arange(b, dtype=jnp.int32)
    big = jnp.int32(cfg.n_nodes)             # sentinel beyond any node id
    node_k = jnp.where(active, node, big + lane)   # unique parking spots

    perm = jnp.lexsort((lane, cs, node_k))
    inv = jnp.argsort(perm)
    ns = node_k[perm]
    cssrt = cs[perm]
    act_s = active[perm]

    prev_node = jnp.concatenate([jnp.full((1,), -2, ns.dtype), ns[:-1]])
    prev_cs = jnp.concatenate([jnp.full((1,), -2, cssrt.dtype), cssrt[:-1]])
    new_node = ns != prev_node
    new_local = new_node | (cssrt != prev_cs)

    node_gid = _ids_from_flags(new_node)
    local_gid = _ids_from_flags(new_local)
    ones = jnp.ones((b,), jnp.int32)

    node_size_g = _segment_stat(ones, node_gid, b)
    local_size_g = _segment_stat(ones, local_gid, b)
    node_start_g = _segment_stat(lane, node_gid, b, "min")     # sorted pos
    local_start_g = _segment_stat(lane, local_gid, b, "min")

    pos = lane                                        # position in sorted order
    node_rank_s = pos - node_start_g[node_gid]
    local_rank_s = pos - local_start_g[local_gid]

    # serialization rank of each (cs,node) group among groups on same node:
    # count local-group heads on this node before me.
    head_flag = new_local.astype(jnp.int32)
    heads_before = jnp.cumsum(head_flag) - head_flag
    node_first_head = _segment_stat(heads_before + head_flag, node_gid, b,
                                    "min")[node_gid]
    cs_rank_s = (heads_before + head_flag) - node_first_head
    n_cs_on_node_s = _segment_stat(head_flag, node_gid, b)[node_gid]

    # remote lock cycles of the local group: first acquire + re-acquire after
    # every MAX_DEPTH handovers (paper lines 24-28).
    k = local_size_g[local_gid]
    cycles_s = (k + cfg.handover_max) // (cfg.handover_max + 1)
    # verb-plane masks: per handover cycle, one lane CASes (its head) and
    # one lane releases (its end — the depth cap or the last of the queue);
    # their counts per group both equal ``cycles_s``
    cyc_pos = local_rank_s % (cfg.handover_max + 1)
    cycle_head_s = cyc_pos == 0
    chain_end_s = (cyc_pos == cfg.handover_max) | (local_rank_s == k - 1)

    def unsort(x):
        return x[inv]

    n_node_groups = jnp.sum(new_node & act_s)
    n_local_groups = jnp.sum(new_local & act_s)
    return Groups(
        perm=perm, inv=inv,
        local_rank=unsort(local_rank_s), local_size=unsort(k),
        local_head=unsort(new_local),
        cycle_head=unsort(cycle_head_s), chain_end=unsort(chain_end_s),
        node_rank=unsort(node_rank_s),
        node_size=unsort(node_size_g[node_gid]),
        node_head=unsort(new_node),
        cs_rank=unsort(cs_rank_s),
        n_cs_on_node=unsort(n_cs_on_node_s),
        lock_cycles=unsort(cycles_s),
        n_node_groups=n_node_groups, n_local_groups=n_local_groups,
    )


def reset_glt(state, ms: int):
    """Crash of memory server ``ms``: its GLT lives in NIC on-chip SRAM,
    so a crash *zeroes* that server's lock rows (every lock word free,
    every in-flight handover chain broken).

    The functional plane acquires and releases within a write phase —
    between waves the GLT is quiescent — so the reset is a semantic
    no-op at wave boundaries; its job is to make the on-chip loss
    explicit so recovery can assert the post-restart lock state is clean
    and the chaos tests can pin that locks are *free*, not leaked, after
    a crash (DESIGN.md §13).  CS-side LLT wait queues are untouched:
    they are compute-server memory and survive an MS crash.
    """
    return state._replace(glt=state.glt.at[ms].set(0))


def cross_cs_contention(leaves_by_cs) -> dict:
    """Cross-CS conflict decomposition of one cluster wave (numpy, host).

    ``leaves_by_cs`` is one array of target-leaf rows per compute server
    (active write lanes only).  In the cluster plane each CS computes its
    HOCL groups privately (:func:`group_by_node` over its own batch), so
    cross-CS contention is *not* visible to any single CS — this helper
    gives the scheduler the merged view: how many nodes are contended by
    more than one CS, the worst per-node CS fan-in, and the number of
    cross-CS (CS, node) conflict pairs whose GLT serialization the trace
    merge chains (`verbs.merge_traces`).
    """
    import numpy as np
    pairs = [(np.unique(np.asarray(lv)), c)
             for c, lv in enumerate(leaves_by_cs)
             if np.asarray(lv).size]
    if not pairs:
        return dict(contended_nodes=0, max_cs_fanin=0, cross_pairs=0)
    nodes = np.concatenate([p[0] for p in pairs])
    uniq, counts = np.unique(nodes, return_counts=True)
    contended = counts > 1
    return dict(contended_nodes=int(contended.sum()),
                max_cs_fanin=int(counts.max()),
                cross_pairs=int((counts[contended] - 1).sum()))


def lock_phase_stats(cfg: TreeConfig, g: Groups, active: jax.Array):
    """Scalar lock-plane counters for one write phase (netsim inputs)."""
    act = active
    zero = jnp.int32(0)
    sel = lambda x: jnp.where(act, x, zero)
    # Sherman/HOCL: remote CAS issued once per lock cycle by group heads.
    hocl_cas = jnp.sum(jnp.where(act & g.local_head, g.lock_cycles, zero))
    # handovers: ops served without a remote acquisition
    handovers = jnp.sum(sel(g.local_size * 0 + 1)) - jnp.sum(
        jnp.where(act & g.local_head, g.lock_cycles, zero))
    # no-hierarchy baseline: every op CASes remotely; a lane at global node
    # rank r burns ~r failed attempts while the r earlier ops hold the lock.
    flat_cas = jnp.sum(sel(g.node_rank + 1))
    # queue depth distribution drives tail latency in netsim
    max_node_group = jnp.max(jnp.where(act, g.node_size, zero))
    max_cs_depth = jnp.max(jnp.where(act, g.cs_rank, zero))
    return dict(hocl_remote_cas=hocl_cas, handovers=handovers,
                flat_remote_cas=flat_cas, max_node_group=max_node_group,
                max_cs_depth=max_cs_depth)
