"""Network cost model: prices structural phase counters in RDMA terms.

The container has no RDMA fabric, so — exactly like the paper explains its
own numbers in §5.5 — performance is *derived* from measured structural
metrics (round trips, message counts, write bytes, conflict-group shapes).
The functional plane (what the tree does) is real JAX execution; this module
only attaches times to it.

Constants (paper sources):
  * RTT ≈ 2 µs for small one-sided verbs at 100 Gbps (§2.2)
  * RDMA_WRITE rate: >50 Mops for IO ≤ 128 B, bandwidth-bound above (Fig. 3)
  * on-chip RDMA_CAS ≈ 110 Mops — no PCIe at MS side (§4.3)
  * host-memory RDMA_CAS needs 2 PCIe transactions; conflicting commands on
    the same NIC bucket serialize on that PCIe time (§3.2.2, Fig. 2)

Queueing model (documented in docs/DESIGN.md §5): ops contending for one node
lock serialize FIFO under HOCL (wait = rank × hold).  Without the local
lock hierarchy, waiters spin with random success, burning one CAS per hold
interval — so CAS traffic on a hot lock grows ~quadratically with the group
size, which is precisely the Fig. 2 collapse.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Features:
    """Sherman's technique toggles — the Fig. 10/11 ablation axis."""
    combine: bool = True       # command combination (§4.5)
    onchip: bool = True        # GLT in NIC on-chip memory (§4.3)
    hierarchical: bool = True  # LLT + wait queues + handover (§4.3)
    twolevel: bool = True      # two-level versions, unsorted leaves (§4.4)

    def label(self) -> str:
        steps = [("C", self.combine), ("O", self.onchip),
                 ("H", self.hierarchical), ("V", self.twolevel)]
        return "".join(s for s, on in steps if on) or "FG+"


FG_PLUS = Features(False, False, False, False)
SHERMAN = Features(True, True, True, True)
ABLATION_LADDER = [
    ("FG+", FG_PLUS),
    ("+Combine", Features(True, False, False, False)),
    ("+On-Chip", Features(True, True, False, False)),
    ("+Hierarchical", Features(True, True, True, False)),
    ("+2-Level Ver", SHERMAN),
]


@dataclasses.dataclass(frozen=True)
class NetConfig:
    rtt_s: float = 2e-6              # one-sided verb round trip
    nic_bw_Bps: float = 12.5e9       # 100 Gbps
    nic_iops_small: float = 50e6     # ≤128 B messages (Fig. 3)
    small_io_bytes: int = 128
    cas_onchip_s: float = 1 / 110e6  # service time per on-chip CAS
    cas_pcie_s: float = 0.9e-6       # two PCIe transactions (host CAS)
    handover_max: int = 4


def _msg_time(n_msgs, total_bytes, n_ms, net: NetConfig):
    """NIC occupancy of a message stream spread over n_ms servers."""
    iops = n_msgs / (n_ms * net.nic_iops_small)
    bw = total_bytes / (n_ms * net.nic_bw_Bps)
    return max(iops, bw)


def price_write_phase(stats: dict, feat: Features, net: NetConfig,
                      n_ms: int, entry_bytes: int, node_bytes: int):
    """Price one write phase.

    ``stats`` holds numpy views of WriteStats.  Returns a dict with per-op
    latency array (seconds), makespan, throughput, plus internal metrics
    (round trips per op, write bytes per op, CAS retries) matching the
    paper's §5.5 reporting.
    """
    act = np.asarray(stats["active"], bool)
    n = int(act.sum())
    if n == 0:
        return dict(latency_s=np.zeros(0), makespan_s=0.0, mops=0.0,
                    rtts=np.zeros(0), write_bytes=np.zeros(0),
                    cas_msgs=0, msgs=0, bytes=0)

    local_rank = np.asarray(stats["local_rank"])[act]
    node_rank = np.asarray(stats["node_rank"])[act]
    node_size = np.asarray(stats["node_size"])[act]
    split_lane = np.asarray(stats["split_lane"], bool)[act]
    cache_hit = np.asarray(stats["cache_hit"], bool)[act]
    height = int(stats["height"])
    m = float(np.max(node_size, initial=1))          # hottest-node fan-in

    # ---- per-op round trips (paper §3.2.1 / §5.5.2) ----
    read_rtts = np.where(cache_hit, 1, height)      # leaf read (+ traversal)
    if feat.hierarchical:
        # group head acquires; handover recipients skip the remote acquire,
        # with a fresh acquire every MAX_DEPTH+1 ops (paper lines 24-28)
        lock_rtts = (local_rank % (net.handover_max + 1) == 0).astype(int)
    else:
        lock_rtts = np.ones(n, int)
    write_rtts = 1 if feat.combine else 2           # write-back [+ unlock]
    rtts = read_rtts + lock_rtts + write_rtts
    # splits: sibling + parent updates; same-MS sibling rides the combined
    # command list (§4.5), priced at phase level below
    rtts = rtts + np.where(split_lane, 2, 0)

    # ---- lock plane (the Fig. 2 physics) ----
    # critical section: read + write(+unlock) after acquiring the lock
    hold_s = (1 + write_rtts) * net.rtt_s
    cas_service = net.cas_onchip_s if feat.onchip else net.cas_pcie_s
    if feat.hierarchical:
        # FIFO via the LLT wait queue: one remote CAS per lock cycle; the
        # queue makes waits deterministic (fairness => tight tail)
        attempts = (local_rank % (net.handover_max + 1) == 0).astype(
            np.float64)
        wait_s = node_rank * hold_s
        # CAS pressure on the hottest lock: one per handover cycle
        hot_cas = np.ceil(m / (net.handover_max + 1))
    else:
        # spinning: every waiter retries once per hold interval until it
        # wins => op at rank r burns ~r*hold/rtt CAS (paper §3.2.2);
        # NO fairness: stragglers wait ~2x their rank (random winner)
        attempts = 1 + node_rank * (hold_s / net.rtt_s)
        tail = node_rank >= 0.8 * np.maximum(node_size, 1)
        wait_s = node_rank * (1.0 + tail) * hold_s
        hot_cas = m + (hold_s / net.rtt_s) * m * m / 2.0
    # failed CAS also serialize on the NIC's per-bucket atomic unit; with
    # host-memory atomics each one occupies ~2 PCIe transactions (§3.2.2)
    hot_atomic_s = hot_cas * cas_service
    wait_s = wait_s + np.minimum(node_rank, 1) * hot_atomic_s \
        * (0.0 if feat.hierarchical else 1.0)
    cas_msgs = int(attempts.sum())

    # ---- bytes (two-level versions => entry-granular write-back) ----
    wr_bytes = np.where(split_lane, 2 * node_bytes,
                        entry_bytes if feat.twolevel else node_bytes)
    rd_bytes = read_rtts * node_bytes
    total_bytes = float(wr_bytes.sum() + rd_bytes.sum()) \
        + cas_msgs * net.small_io_bytes
    msgs = int(rtts.sum()) + cas_msgs

    # ---- latency & makespan ----
    latency = rtts * net.rtt_s + wait_s + \
        np.where(wr_bytes > net.small_io_bytes,
                 wr_bytes / net.nic_bw_Bps, 0.0)
    makespan = max(
        _msg_time(msgs, total_bytes, n_ms, net),   # NIC occupancy
        m * hold_s,                                # hottest node serializes
        hot_atomic_s,                              # hottest lock bucket
        float(np.median(latency)),                 # pipeline floor
    )
    return dict(latency_s=latency, makespan_s=makespan,
                mops=n / makespan / 1e6, rtts=rtts,
                write_bytes=wr_bytes, cas_msgs=cas_msgs, msgs=msgs,
                bytes=total_bytes)


def price_read_phase(stats: dict, feat: Features, net: NetConfig,
                     n_ms: int, node_bytes: int):
    """Price a lookup phase: 1 read RTT on cache hit + version retries.

    When the caller measured the reads directly (the functional index
    cache reports per-lane ``remote_reads``), that count is priced as-is;
    otherwise round trips are derived from ``cache_hit``/``height``.
    """
    act = np.asarray(stats["active"], bool)
    n = int(act.sum())
    if n == 0:
        return dict(latency_s=np.zeros(0), makespan_s=0.0, mops=0.0,
                    rtts=np.zeros(0), bytes=0.0)
    retries = np.asarray(stats["retries"])[act] if "retries" in stats \
        else np.zeros(n)
    if "remote_reads" in stats:
        rtts = np.asarray(stats["remote_reads"])[act] + retries
    else:
        cache_hit = np.asarray(stats["cache_hit"], bool)[act]
        height = int(stats["height"])
        rtts = np.where(cache_hit, 1, height) + retries
    bytes_ = float(rtts.sum()) * node_bytes
    latency = rtts * net.rtt_s + node_bytes / net.nic_bw_Bps
    makespan = max(_msg_time(float(rtts.sum()), bytes_, n_ms, net),
                   float(np.median(latency)))
    return dict(latency_s=latency, makespan_s=makespan,
                mops=n / makespan / 1e6, rtts=rtts, bytes=bytes_)


# The byte-counting ``IndexCacheSim`` stub that used to live here was
# replaced by the functional CS-side cache subsystem in
# :mod:`repro.core.cache` (hits are exercised, not merely priced); this
# module now only attaches costs to the hit/miss/stale counts it reports.
