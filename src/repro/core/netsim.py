"""netsim — a discrete-event RDMA simulator over verb traces.

The container has no RDMA fabric, so — exactly like the paper explains its
own numbers in §5.5 — performance is *derived* from the functional plane.
What changed from the original counter-pricing model: the functional plane
now emits a structured **verb trace** (:mod:`repro.core.verbs` — one record
per READ/WRITE/CAS a real CS would post, with target MS, payload, doorbell
grouping and dependency links), and this module replays that trace in an
event loop against per-MS resources.  Per-op latency, tail percentiles and
phase makespan *fall out of the replay* instead of closed-form formulas.

Resources (paper sources):

  * RTT ≈ 2 µs for small one-sided verbs at 100 Gbps (§2.2);
  * per-MS **NIC message unit**: >50 Mops for IO ≤ 128 B, bandwidth-bound
    above (Fig. 3) — every verb occupies it FIFO;
  * per-MS **atomic unit**: CAS additionally serialize here — NIC on-chip
    ≈ 110 Mops (§4.3) vs. ~2 PCIe transactions ≈ 0.9 µs for host-memory
    atomics (§3.2.2, Fig. 2).  The quadratic spin-CAS load of a hot lock
    clogging the PCIe-cost atomic unit *is* the Fig. 2 collapse.

Sherman's feature toggles carry **no closed-form constants here**; they are

  * ``combine``      → :func:`repro.core.verbs.combine_doorbells`
  * ``hierarchical`` → :func:`repro.core.verbs.hierarchical_locks`
  * ``twolevel``     → :func:`repro.core.verbs.twolevel_writes`
  * ``onchip``       → the atomic-unit service-time *resource parameter*.

Event-loop semantics and the verb taxonomy are documented in
docs/DESIGN.md §10.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core import verbs as V


@dataclasses.dataclass(frozen=True)
class Features:
    """Sherman's technique toggles — the Fig. 10/11 ablation axis."""
    combine: bool = True       # command combination (§4.5)
    onchip: bool = True        # GLT in NIC on-chip memory (§4.3)
    hierarchical: bool = True  # LLT + wait queues + handover (§4.3)
    twolevel: bool = True      # two-level versions, unsorted leaves (§4.4)

    def label(self) -> str:
        steps = [("C", self.combine), ("O", self.onchip),
                 ("H", self.hierarchical), ("V", self.twolevel)]
        return "".join(s for s, on in steps if on) or "FG+"


FG_PLUS = Features(False, False, False, False)
SHERMAN = Features(True, True, True, True)
ABLATION_LADDER = [
    ("FG+", FG_PLUS),
    ("+Combine", Features(True, False, False, False)),
    ("+On-Chip", Features(True, True, False, False)),
    ("+Hierarchical", Features(True, True, True, False)),
    ("+2-Level Ver", SHERMAN),
]


@dataclasses.dataclass(frozen=True)
class NetConfig:
    rtt_s: float = 2e-6              # one-sided verb round trip
    nic_bw_Bps: float = 12.5e9       # 100 Gbps
    nic_iops_small: float = 50e6     # ≤128 B messages (Fig. 3)
    small_io_bytes: int = 128
    cas_onchip_s: float = 1 / 110e6  # service time per on-chip CAS
    cas_pcie_s: float = 0.9e-6       # two PCIe transactions (host CAS)
    handover_max: int = 4


# --------------------------------------------------------------------------
# the event loop
# --------------------------------------------------------------------------

def simulate(trace: V.VerbTrace, net: NetConfig, n_ms: int,
             onchip: bool) -> dict:
    """Replay one phase's verb trace against per-MS resources.

    Every verb is posted when its gates (``dep``/``dep2`` completions and
    its ``at`` floor) allow, occupies the target MS's NIC message unit
    FIFO (``max(1/iops, bytes/bw)``), CAS additionally serialize on the
    MS's atomic unit, and the client observes completion one RTT after
    service.  Verbs sharing a doorbell inherit the head's gates (set by
    the combine transformation), so they post together and per-MS FIFO
    order keeps in-order delivery.

    Returns per-lane latency (completion of the lane's last verb — the
    wave starts at t=0), the phase makespan, and trace totals.
    """
    n = trace.n_verbs
    n_lanes = trace.n_lanes
    if n == 0:
        return dict(latency_s=np.zeros(n_lanes), makespan_s=0.0,
                    rtts=np.zeros(n_lanes, np.int64),
                    write_bytes=np.zeros(n_lanes),
                    msgs=0, verbs=0, bytes=0.0, cas_msgs=0, doorbells=0)

    svc = np.maximum(1.0 / net.nic_iops_small,
                     trace.nbytes / net.nic_bw_Bps).tolist()
    cas_s = net.cas_onchip_s if onchip else net.cas_pcie_s
    rtt = net.rtt_s
    kind = trace.kind.tolist()
    ms = trace.ms.tolist()
    at = trace.at.tolist()
    dep = trace.dep.tolist()
    dep2 = trace.dep2.tolist()

    npend = ((trace.dep >= 0).astype(np.int8)
             + (trace.dep2 >= 0).astype(np.int8))
    children: list[list[int]] = [[] for _ in range(n)]
    for col in (trace.dep, trace.dep2):
        for i in np.nonzero(col >= 0)[0].tolist():
            children[col[i]].append(i)
    npend = npend.tolist()

    heap = [(at[i], i) for i in np.nonzero(
        (trace.dep < 0) & (trace.dep2 < 0))[0].tolist()]
    heapq.heapify(heap)
    nic_free = [0.0] * n_ms
    atomic_free = [0.0] * n_ms
    comp = [0.0] * n
    push, pop = heapq.heappush, heapq.heappop
    while heap:
        t, i = pop(heap)
        m = ms[i]
        s = t if t > nic_free[m] else nic_free[m]
        d = s + svc[i]
        nic_free[m] = d
        if kind[i] == V.CAS:
            a = d if d > atomic_free[m] else atomic_free[m]
            d = a + cas_s
            atomic_free[m] = d
        d += rtt
        comp[i] = d
        for c in children[i]:
            npend[c] -= 1
            if not npend[c]:
                r = at[c]
                j = dep[c]
                if j >= 0 and comp[j] > r:
                    r = comp[j]
                j = dep2[c]
                if j >= 0 and comp[j] > r:
                    r = comp[j]
                push(heap, (r, c))

    comp = np.asarray(comp)
    lat = np.zeros(n_lanes)
    lm = trace.lane >= 0
    np.maximum.at(lat, trace.lane[lm], comp[lm])
    return dict(latency_s=lat, makespan_s=float(comp.max()),
                rtts=trace.per_lane_doorbells(),
                write_bytes=trace.per_lane_write_bytes(),
                msgs=n, verbs=n, bytes=trace.total_bytes,
                cas_msgs=trace.n_cas, doorbells=trace.n_doorbells)


def transformed_write_trace(stats: dict, feat: Features, net: NetConfig,
                            cfg) -> V.VerbTrace:
    """Canonical write trace + the feature transformations, in order
    (lock-stream rewrite reassembles, so it runs first)."""
    tr = V.write_phase_trace(stats, cfg, net.rtt_s)
    if tr.n_verbs == 0:
        return tr
    if feat.hierarchical:
        tr = V.hierarchical_locks(tr)
    if feat.twolevel:
        tr = V.twolevel_writes(tr)
    if feat.combine:
        tr = V.combine_doorbells(tr)
    return tr


# --------------------------------------------------------------------------
# phase pricing (the api.py entry points)
# --------------------------------------------------------------------------

def price_write_phase(stats: dict, feat: Features, net: NetConfig, cfg):
    """Price one write phase by verb-trace replay.

    ``stats`` holds numpy views of WriteStats (see
    :func:`repro.core.api.write_stats_dict`); ``cfg`` is the TreeConfig
    (MS layout + wire sizes).  Returns the per-op latency array, phase
    makespan, throughput, and trace totals (verbs, doorbells, bytes,
    CAS), matching the paper's §5.5 reporting.
    """
    tr = transformed_write_trace(stats, feat, net, cfg)
    sim = simulate(tr, net, cfg.n_ms, feat.onchip)
    n = tr.n_lanes
    sim["mops"] = n / sim["makespan_s"] / 1e6 if sim["makespan_s"] else 0.0
    return sim


def read_trace_from_stats(stats: dict, cfg) -> V.VerbTrace:
    """Build a lookup/scan phase's READ-chain trace from its stats dict.

    When the caller measured the reads directly (the functional index
    cache reports per-lane ``remote_reads``), that count is replayed
    as-is; otherwise it derives from ``cache_hit``/``height``.  Version
    ``retries`` (e.g. extra leaves of a scan) extend the chain and are
    clamped at zero — an empty scan still pays its initial descent.
    Shared by :func:`price_read_phase` and the cluster plane's per-CS
    trace collection (:mod:`repro.cluster.sched`).
    """
    act = np.asarray(stats["active"], bool)
    n = int(act.sum())
    if n == 0:
        return V._empty_trace()
    retries = np.maximum(np.asarray(stats["retries"])[act], 0) \
        if "retries" in stats else np.zeros(n, np.int64)
    if "remote_reads" in stats:
        reads = np.asarray(stats["remote_reads"])[act] + retries
    else:
        cache_hit = np.asarray(stats["cache_hit"], bool)[act]
        reads = np.where(cache_hit, 1, max(int(stats["height"]), 1)) \
            + retries
    if "leaf" in stats:
        leaf_ms = cfg.ms_of(np.asarray(stats["leaf"])[act].astype(np.int64))
    else:
        leaf_ms = np.arange(n, dtype=np.int64) % cfg.n_ms
    return V.read_phase_trace(reads, leaf_ms, cfg.n_ms, cfg.node_bytes,
                              scan=bool(stats.get("scan", False)))


def price_read_phase(stats: dict, feat: Features, net: NetConfig, cfg):
    """Price a lookup/scan phase: sequential READ chains per lane
    (see :func:`read_trace_from_stats` for the trace semantics)."""
    n = int(np.asarray(stats["active"], bool).sum())
    if n == 0:
        return dict(latency_s=np.zeros(0), makespan_s=0.0, mops=0.0,
                    rtts=np.zeros(0, np.int64), msgs=0, verbs=0, bytes=0.0,
                    cas_msgs=0, doorbells=0)
    tr = read_trace_from_stats(stats, cfg)
    sim = simulate(tr, net, cfg.n_ms, feat.onchip)
    sim["mops"] = n / sim["makespan_s"] / 1e6 if sim["makespan_s"] else 0.0
    return sim


def price_merged_phase(traces: list[V.VerbTrace], feat: Features,
                       net: NetConfig, cfg):
    """Price one cluster wave: merge per-CS traces into one timeline and
    replay it against the *shared* per-MS resources.

    Returns ``(sim, merged)``: the usual :func:`simulate` totals (per
    merged lane latency, makespan, verb/byte/doorbell counts) plus the
    merged trace itself so the caller can attribute lanes back to their
    source CS via ``merged.meta['lane_cs']``.  Cross-CS GLT serialization
    and NIC/atomic-unit queueing are emergent — see
    :func:`repro.core.verbs.merge_traces`.
    """
    merged = V.merge_traces(traces)
    sim = simulate(merged, net, cfg.n_ms, feat.onchip)
    return sim, merged


def price_maintenance(node_reads: int, small_reads: int, feat: Features,
                      net: NetConfig, cfg, rows_ms=None):
    """Price the CS cache's background traffic (image fills + version
    sweeps) by replaying its MAINT/SYNC read verbs."""
    tr = V.maintenance_trace(node_reads, small_reads, cfg.n_ms,
                             cfg.node_bytes, net.small_io_bytes,
                             rows_ms=rows_ms)
    return simulate(tr, net, cfg.n_ms, feat.onchip)


# The closed-form counter pricing that used to live here (per-feature RTT
# constants such as ``write_rtts = 1 if feat.combine else 2``) was replaced
# by the verb-trace plane above; the byte-counting ``IndexCacheSim`` stub
# before it lives on as the functional cache in :mod:`repro.core.cache`.
