"""netsim — a discrete-event RDMA simulator over verb traces.

The container has no RDMA fabric, so — exactly like the paper explains its
own numbers in §5.5 — performance is *derived* from the functional plane.
The functional plane emits a structured **verb trace**
(:mod:`repro.core.verbs` — one record per READ/WRITE/CAS a real CS would
post, with target MS, payload, doorbell grouping and dependency links),
and this module replays that trace against per-MS resources.  Per-op
latency, tail percentiles and phase makespan *fall out of the replay*
instead of closed-form formulas.

Two equivalent replay engines share one integer time grid (picoseconds,
so event ordering is exact and deterministic — no float tie-breaking):

* :func:`simulate` — the production engine: a vectorized
  structure-of-arrays replay (topological wavefront over ``dep``/``dep2``
  with a conservative time horizon, per-MS lexsort + cumulative-max
  service times).  Interpreter cost scales with the number of *waves*,
  not the number of verbs, so paper-scale traces replay in milliseconds.
* :func:`simulate_ref` — the original per-verb heapq event loop, kept as
  the executable specification.  ``simulate`` is exactly equivalent
  (same int64 completion times; asserted by tests/test_throughput.py on
  real SHERMAN/FG+/merged-cluster traces).

Resources (paper sources):

  * RTT ≈ 2 µs for small one-sided verbs at 100 Gbps (§2.2);
  * per-MS **NIC message unit**: >50 Mops for IO ≤ 128 B, bandwidth-bound
    above (Fig. 3) — every verb occupies it FIFO;
  * per-MS **atomic unit**: CAS additionally serialize here — NIC on-chip
    ≈ 110 Mops (§4.3) vs. ~2 PCIe transactions ≈ 0.9 µs for host-memory
    atomics (§3.2.2, Fig. 2).  The quadratic spin-CAS load of a hot lock
    clogging the PCIe-cost atomic unit *is* the Fig. 2 collapse.

Sherman's feature toggles carry **no closed-form constants here**; they are

  * ``combine``      → :func:`repro.core.verbs.combine_doorbells`
  * ``hierarchical`` → :func:`repro.core.verbs.hierarchical_locks`
  * ``twolevel``     → :func:`repro.core.verbs.twolevel_writes`
  * ``onchip``       → the atomic-unit service-time *resource parameter*.

Event-loop semantics, the verb taxonomy, and the wavefront algorithm's
exactness argument are documented in docs/DESIGN.md §10.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core import verbs as V

#: Integer time grid: one tick = 1 ps.  All service times, RTTs and
#: ``at`` floors are rounded onto the grid once, so both replay engines
#: do exact int64 arithmetic and make identical ordering decisions.
PS_PER_S = 1e12


@dataclasses.dataclass(frozen=True)
class Features:
    """Sherman's technique toggles — the Fig. 10/11 ablation axis."""
    combine: bool = True       # command combination (§4.5)
    onchip: bool = True        # GLT in NIC on-chip memory (§4.3)
    hierarchical: bool = True  # LLT + wait queues + handover (§4.3)
    twolevel: bool = True      # two-level versions, unsorted leaves (§4.4)

    def label(self) -> str:
        steps = [("C", self.combine), ("O", self.onchip),
                 ("H", self.hierarchical), ("V", self.twolevel)]
        return "".join(s for s, on in steps if on) or "FG+"


FG_PLUS = Features(False, False, False, False)
SHERMAN = Features(True, True, True, True)
ABLATION_LADDER = [
    ("FG+", FG_PLUS),
    ("+Combine", Features(True, False, False, False)),
    ("+On-Chip", Features(True, True, False, False)),
    ("+Hierarchical", Features(True, True, True, False)),
    ("+2-Level Ver", SHERMAN),
]


@dataclasses.dataclass(frozen=True)
class NetConfig:
    rtt_s: float = 2e-6              # one-sided verb round trip
    nic_bw_Bps: float = 12.5e9       # 100 Gbps
    nic_iops_small: float = 50e6     # ≤128 B messages (Fig. 3)
    small_io_bytes: int = 128
    cas_onchip_s: float = 1 / 110e6  # service time per on-chip CAS
    cas_pcie_s: float = 0.9e-6       # two PCIe transactions (host CAS)
    handover_max: int = 4


@dataclasses.dataclass
class ServerClock:
    """Carried per-MS busy frontiers (int64 ps) — the open-loop serving
    plane's absolute shared timeline.

    Closed-loop phase pricing starts every MS idle at t=0: each phase is
    its own relative timeline and makespans are summed.  Passing a clock
    to :func:`simulate` / :func:`simulate_ref` instead seeds the NIC
    message unit and atomic unit from the carried busy times and writes
    the advanced frontiers back, so successive waves replay on ONE
    absolute timeline: an op whose ``at`` release gate says it arrived at
    absolute time *t* queues behind everything the servers already
    accepted.  Host-side wave chunking then has no timing effect —
    replaying a trace in one call or split across many calls with the
    carried clock yields identical completion ticks
    (tests/test_serve_queueing.py pins this invariance).
    """

    nic_free_ps: np.ndarray
    atomic_free_ps: np.ndarray
    #: Optional observability-plane recorder (repro.obs) carried with the
    #: clock across open-loop waves; replays on this clock capture into
    #: it unless the caller passes an explicit recorder.
    recorder: object | None = None

    @classmethod
    def fresh(cls, n_ms: int) -> "ServerClock":
        return cls(np.zeros(n_ms, np.int64), np.zeros(n_ms, np.int64))

    @property
    def now_s(self) -> float:
        """Latest server busy frontier, in seconds."""
        hi = max(int(self.nic_free_ps.max(initial=0)),
                 int(self.atomic_free_ps.max(initial=0)))
        return hi / PS_PER_S

    def reset_ms(self, ms: int, restart_s: float) -> None:
        """Crash/restart of memory server ``ms``: its NIC message unit
        and atomic unit come back *empty* at ``restart_s``.

        A crash destroys the on-NIC queue — whatever backlog the dead
        server had accepted is gone, not carried.  Without this reset a
        restarted MS would keep its pre-crash busy frontier and verbs
        released after the restart would queue behind phantom work
        (tests/test_netsim_trace.py pins the single-verb latency).  The
        frontier is set to the restart tick itself: the server cannot
        serve before it is back, and it owes nothing from before.
        """
        t = np.int64(round(float(restart_s) * PS_PER_S))
        self.nic_free_ps[ms] = t
        self.atomic_free_ps[ms] = t


# --------------------------------------------------------------------------
# shared grid + result assembly
# --------------------------------------------------------------------------

def _grid_times(trace: V.VerbTrace, net: NetConfig, onchip: bool):
    """Round one trace's timing constants onto the shared ps grid."""
    svc = np.rint(np.maximum(1.0 / net.nic_iops_small,
                             trace.nbytes / net.nic_bw_Bps) * PS_PER_S)
    cas = (net.cas_onchip_s if onchip else net.cas_pcie_s) * PS_PER_S
    return (svc.astype(np.int64), int(round(cas)),
            int(round(net.rtt_s * PS_PER_S)),
            np.rint(np.asarray(trace.at) * PS_PER_S).astype(np.int64))


def _empty_sim(n_lanes: int) -> dict:
    return dict(latency_s=np.zeros(n_lanes), makespan_s=0.0,
                lane_doorbells=np.zeros(n_lanes, np.int64),
                write_bytes=np.zeros(n_lanes),
                lane_queue_s=np.zeros(n_lanes),
                verb_start_s=np.zeros(0),
                msgs=0, verbs=0, bytes=0.0, cas_msgs=0, doorbells=0)


def _finish_sim(trace: V.VerbTrace, comp_ps: np.ndarray,
                wait_ps: np.ndarray, start_ps: np.ndarray) -> dict:
    """Fold per-verb completion ticks into the phase's reported totals.

    ``lane_doorbells`` is the per-lane doorbell-ring count
    (``VerbTrace.per_lane_doorbells`` in :mod:`repro.core.verbs`) — the
    sequential posting-depth metric; for read phases every READ is its
    own ring, so there it equals the lane's remote reads.

    ``lane_queue_s`` is the lane's total **queueing delay**: per-verb
    wait for the NIC message unit plus (for CAS) the atomic unit, summed
    over the lane's verbs.  Waiting on a dependency (``dep``/``dep2``)
    or an ``at`` release gate is not queueing — the verb is not yet
    posted.  ``verb_start_s`` is each verb's NIC service start, so
    release-gate invariants (no verb starts before its op arrived) are
    checkable per verb.
    """
    comp = comp_ps * (1.0 / PS_PER_S)
    lat = np.zeros(trace.n_lanes)
    lm = trace.lane >= 0
    np.maximum.at(lat, trace.lane[lm], comp[lm])
    queue = np.zeros(trace.n_lanes)
    np.add.at(queue, trace.lane[lm], wait_ps[lm] * (1.0 / PS_PER_S))
    return dict(latency_s=lat, makespan_s=float(comp.max()),
                lane_doorbells=trace.per_lane_doorbells(),
                write_bytes=trace.per_lane_write_bytes(),
                lane_queue_s=queue,
                verb_start_s=start_ps * (1.0 / PS_PER_S),
                msgs=trace.n_verbs, verbs=trace.n_verbs,
                bytes=trace.total_bytes,
                cas_msgs=trace.n_cas, doorbells=trace.n_doorbells)


# --------------------------------------------------------------------------
# the reference event loop (executable specification)
# --------------------------------------------------------------------------

def _resolve_recorder(recorder, clock):
    """The replay's capture target: an explicit recorder wins, else the
    one carried by the ServerClock (open-loop waves), else none."""
    if recorder is not None:
        return recorder
    return clock.recorder if clock is not None else None


def simulate_ref(trace: V.VerbTrace, net: NetConfig, n_ms: int,
                 onchip: bool, clock: ServerClock | None = None,
                 recorder=None) -> dict:
    """Per-verb heapq replay — the specification :func:`simulate` must
    match tick-for-tick.

    Every verb is posted when its gates (``dep``/``dep2`` completions and
    its ``at`` floor) allow, occupies the target MS's NIC message unit
    FIFO (``max(1/iops, bytes/bw)``), CAS additionally serialize on the
    MS's atomic unit, and the client observes completion one RTT after
    service.  Verbs sharing a doorbell inherit the head's gates (set by
    the combine transformation), so they post together and per-MS FIFO
    order keeps in-order delivery.

    With a :class:`ServerClock` the busy frontiers seed from (and write
    back to) the carried per-MS state — the open-loop absolute timeline.
    ``recorder`` (or one carried by the clock) captures the replay's
    per-verb timing after the fact — a pure observation, so recorded
    and unrecorded runs are bit-identical (repro.obs.recorder).
    """
    n = trace.n_verbs
    if n == 0:
        return _empty_sim(trace.n_lanes)
    rec = _resolve_recorder(recorder, clock)
    svc_a, cas_s, rtt, at_a = _grid_times(trace, net, onchip)
    svc = svc_a.tolist()
    kind = trace.kind.tolist()
    ms = trace.ms.tolist()
    at = at_a.tolist()
    dep = trace.dep.tolist()
    dep2 = trace.dep2.tolist()

    npend = ((trace.dep >= 0).astype(np.int8)
             + (trace.dep2 >= 0).astype(np.int8))
    children: list[list[int]] = [[] for _ in range(n)]
    for col in (trace.dep, trace.dep2):
        for i in np.nonzero(col >= 0)[0].tolist():
            children[col[i]].append(i)
    npend = npend.tolist()

    heap = [(at[i], i) for i in np.nonzero(
        (trace.dep < 0) & (trace.dep2 < 0))[0].tolist()]
    heapq.heapify(heap)
    nic_free = ([0] * n_ms if clock is None
                else clock.nic_free_ps.tolist())
    atomic_free = ([0] * n_ms if clock is None
                   else clock.atomic_free_ps.tolist())
    comp = [0] * n
    wait = [0] * n
    start = [0] * n
    push, pop = heapq.heappush, heapq.heappop
    while heap:
        t, i = pop(heap)
        m = ms[i]
        s = t if t > nic_free[m] else nic_free[m]
        start[i] = s
        w = s - t
        d = s + svc[i]
        nic_free[m] = d
        if kind[i] == V.CAS:
            a = d if d > atomic_free[m] else atomic_free[m]
            w += a - d
            d = a + cas_s
            atomic_free[m] = d
        d += rtt
        comp[i] = d
        wait[i] = w
        for c in children[i]:
            npend[c] -= 1
            if not npend[c]:
                r = at[c]
                j = dep[c]
                if j >= 0 and comp[j] > r:
                    r = comp[j]
                j = dep2[c]
                if j >= 0 and comp[j] > r:
                    r = comp[j]
                push(heap, (r, c))
    if clock is not None:
        clock.nic_free_ps[:] = nic_free
        clock.atomic_free_ps[:] = atomic_free
    comp_a = np.asarray(comp, np.int64)
    wait_a = np.asarray(wait, np.int64)
    start_a = np.asarray(start, np.int64)
    if rec is not None:
        rec.capture(trace, net, onchip, comp_a, wait_a, start_a,
                    clocked=clock is not None)
    return _finish_sim(trace, comp_a, wait_a, start_a)


# --------------------------------------------------------------------------
# the vectorized wavefront replay (the production engine)
# --------------------------------------------------------------------------

def simulate(trace: V.VerbTrace, net: NetConfig, n_ms: int,
             onchip: bool, clock: ServerClock | None = None,
             recorder=None) -> dict:
    """Vectorized structure-of-arrays replay, exactly equivalent to
    :func:`simulate_ref`.

    Instead of popping one verb at a time, each **wave** batch-services
    every dependency-released verb whose ready time lies below a
    conservative horizon ``T = min(ready) + min(svc) + rtt``: any verb
    still gated by an unfinished dependency completes no earlier than
    ``ready + svc + rtt`` of some released verb, so nothing outside the
    wave can undercut it in its MS's FIFO (DESIGN.md §10 has the full
    argument).  The wave is serviced per MS with a lexsort +
    cumulative-max prefix recurrence (the closed form of the sequential
    ``d_j = max(ready_j, d_{j-1}) + svc_j`` FIFO recursion, seeded with
    the MS's carried busy time), CAS verbs pass through the same
    recurrence again on the atomic unit, and completions release the
    verbs gated on them.  All arithmetic is int64 ticks on the shared
    grid, so ordering ties resolve identically to the reference loop.

    With a :class:`ServerClock` the carried busy frontiers seed the
    recurrences and are written back afterwards (the open-loop absolute
    timeline).  The horizon argument is unaffected: a carried frontier
    only delays service starts, and per-MS FIFO order is decided by
    ready times, which the frontier does not touch.  ``recorder`` — see
    :func:`simulate_ref`; the capture runs after the replay's last
    ordering decision, so it cannot perturb the result.
    """
    n = trace.n_verbs
    if n == 0:
        return _empty_sim(trace.n_lanes)
    rec = _resolve_recorder(recorder, clock)
    svc, cas_ps, rtt_ps, at = _grid_times(trace, net, onchip)
    ms = trace.ms.astype(np.int64)
    kind = trace.kind
    dep, dep2 = trace.dep, trace.dep2
    has1, has2 = dep >= 0, dep2 >= 0
    # child adjacency in CSR form (one edge per dep/dep2 gate)
    par = np.concatenate([dep[has1], dep2[has2]])
    chd = np.concatenate([np.flatnonzero(has1), np.flatnonzero(has2)])
    o = np.argsort(par, kind="stable")
    par_s, chd_s = par[o], chd[o]
    coff = np.searchsorted(par_s, np.arange(n + 1))
    npend = has1.astype(np.int32) + has2.astype(np.int32)
    d1 = np.where(has1, dep, 0)
    d2 = np.where(has2, dep2, 0)

    comp = np.zeros(n, np.int64)
    wait = np.zeros(n, np.int64)
    start = np.zeros(n, np.int64)
    nic_free = (np.zeros(n_ms, np.int64) if clock is None
                else clock.nic_free_ps.copy())
    atomic_free = (np.zeros(n_ms, np.int64) if clock is None
                   else clock.atomic_free_ps.copy())
    look = int(svc.min()) + rtt_ps       # conservative horizon increment

    # static frontier: verbs with no gates, consumed as a sorted cursor
    root = np.flatnonzero(npend == 0)
    ro = np.lexsort((root, at[root]))
    root = root[ro]
    root_at = at[root]
    rp = 0
    dyn_i = np.zeros(0, np.int64)        # dependency-released pool
    dyn_r = np.zeros(0, np.int64)
    done = 0
    while done < n:
        if rp < root.size:
            tstar = int(root_at[rp])
            if dyn_r.size:
                dmin = int(dyn_r.min())
                if dmin < tstar:
                    tstar = dmin
        elif dyn_r.size:
            tstar = int(dyn_r.min())
        else:                            # pool empty => dependency cycle
            raise ValueError("verb trace contains a dependency cycle")
        T = tstar + look
        np_ = rp + int(np.searchsorted(root_at[rp:], T, side="left"))
        S = root[rp:np_]
        R = root_at[rp:np_]
        rp = np_
        if dyn_i.size:
            m_ = dyn_r < T
            S = np.concatenate([S, dyn_i[m_]])
            R = np.concatenate([R, dyn_r[m_]])
            dyn_i, dyn_r = dyn_i[~m_], dyn_r[~m_]
        # FIFO-service the wave per MS: (ms, ready, idx) order matches the
        # reference heap's pop order exactly (ticks are exact ints)
        o2 = np.lexsort((S, R, ms[S]))
        S, R = S[o2], R[o2]
        msS = ms[S]
        starts = np.flatnonzero(
            np.concatenate([[True], msS[1:] != msS[:-1]]))
        bounds = np.append(starts, S.size)
        svcS = svc[S]
        c = np.cumsum(svcS)
        base = R - (c - svcS)
        d = np.empty(S.size, np.int64)
        for a, b in zip(bounds[:-1], bounds[1:]):
            m0 = msS[a]
            hi = np.maximum.accumulate(
                np.maximum(base[a:b], nic_free[m0] - (c[a] - svcS[a])))
            d[a:b] = c[a:b] + hi
            nic_free[m0] = d[b - 1]
        startS = d - svcS                 # NIC service start per verb
        waitS = startS - R               # NIC message-unit queueing
        cm = kind[S] == V.CAS
        if cm.any():
            cpos = np.flatnonzero(cm)
            d_nic = d[cpos].copy()       # NIC completion before atomic pass
            ca = cas_ps * np.arange(1, cpos.size + 1, dtype=np.int64)
            base2 = d[cpos] - (ca - cas_ps)
            seg_of = np.searchsorted(starts, cpos, side="right")
            cb = np.flatnonzero(
                np.concatenate([[True], seg_of[1:] != seg_of[:-1]]))
            cbounds = np.append(cb, cpos.size)
            for a, b in zip(cbounds[:-1], cbounds[1:]):
                m0 = msS[cpos[a]]
                hi = np.maximum.accumulate(
                    np.maximum(base2[a:b],
                               atomic_free[m0] - (ca[a] - cas_ps)))
                d[cpos[a:b]] = ca[a:b] + hi
                atomic_free[m0] = d[cpos[b - 1]]
            waitS[cpos] += (d[cpos] - cas_ps) - d_nic   # atomic-unit wait
        comp[S] = d + rtt_ps
        wait[S] = waitS
        start[S] = startS
        done += S.size
        # release the verbs gated on this wave's completions
        a_, b_ = coff[S], coff[S + 1]
        cnt = b_ - a_
        tot = int(cnt.sum())
        if tot:
            off_ = np.arange(tot) - np.repeat(np.cumsum(cnt) - cnt, cnt)
            kids = chd_s[np.repeat(a_, cnt) + off_]
            np.subtract.at(npend, kids, 1)
            nk = np.unique(kids[npend[kids] == 0])
            if nk.size:
                r_ = np.maximum(at[nk], np.maximum(
                    np.where(has1[nk], comp[d1[nk]], 0),
                    np.where(has2[nk], comp[d2[nk]], 0)))
                dyn_i = np.concatenate([dyn_i, nk])
                dyn_r = np.concatenate([dyn_r, r_])
    if clock is not None:
        clock.nic_free_ps[:] = nic_free
        clock.atomic_free_ps[:] = atomic_free
    if rec is not None:
        rec.capture(trace, net, onchip, comp, wait, start,
                    clocked=clock is not None)
    return _finish_sim(trace, comp, wait, start)


def transformed_write_trace(stats: dict, feat: Features, net: NetConfig,
                            cfg) -> V.VerbTrace:
    """Canonical write trace + the feature transformations, in order
    (lock-stream rewrite reassembles, so it runs first)."""
    tr = V.write_phase_trace(stats, cfg, net.rtt_s)
    if tr.n_verbs == 0:
        return tr
    if feat.hierarchical:
        tr = V.hierarchical_locks(tr)
    if feat.twolevel:
        tr = V.twolevel_writes(tr)
    if feat.combine:
        tr = V.combine_doorbells(tr)
    return tr


# --------------------------------------------------------------------------
# phase pricing (the api.py entry points)
# --------------------------------------------------------------------------

def price_write_phase(stats: dict, feat: Features, net: NetConfig, cfg,
                      recorder=None):
    """Price one write phase by verb-trace replay.

    ``stats`` holds numpy views of WriteStats (see
    :func:`repro.core.api.write_stats_dict`); ``cfg`` is the TreeConfig
    (MS layout + wire sizes).  Returns the per-op latency array, phase
    makespan, throughput, and trace totals (verbs, doorbells, bytes,
    CAS), matching the paper's §5.5 reporting.
    """
    tr = transformed_write_trace(stats, feat, net, cfg)
    sim = simulate(tr, net, cfg.n_ms, feat.onchip, recorder=recorder)
    n = tr.n_lanes
    sim["mops"] = n / sim["makespan_s"] / 1e6 if sim["makespan_s"] else 0.0
    return sim


def read_trace_from_stats(stats: dict, cfg) -> V.VerbTrace:
    """Build a lookup/scan phase's READ-chain trace from its stats dict.

    When the caller measured the reads directly (the functional index
    cache reports per-lane ``remote_reads``), that count is replayed
    as-is; otherwise it derives from ``cache_hit``/``height``.  Version
    ``retries`` (e.g. extra leaves of a scan) extend the chain and are
    clamped at zero — an empty scan still pays its initial descent.
    Shared by :func:`price_read_phase` and the cluster plane's per-CS
    trace collection (:mod:`repro.cluster.sched`).
    """
    act = np.asarray(stats["active"], bool)
    n = int(act.sum())
    if n == 0:
        return V._empty_trace()
    retries = np.maximum(np.asarray(stats["retries"])[act], 0) \
        if "retries" in stats else np.zeros(n, np.int64)
    if "remote_reads" in stats:
        reads = np.asarray(stats["remote_reads"])[act] + retries
    else:
        cache_hit = np.asarray(stats["cache_hit"], bool)[act]
        reads = np.where(cache_hit, 1, max(int(stats["height"]), 1)) \
            + retries
    if "leaf" in stats:
        leaf_ms = cfg.ms_of(np.asarray(stats["leaf"])[act].astype(np.int64))
    else:
        leaf_ms = np.arange(n, dtype=np.int64) % cfg.n_ms
    return V.read_phase_trace(reads, leaf_ms, cfg.n_ms, cfg.node_bytes,
                              scan=bool(stats.get("scan", False)))


def price_read_phase(stats: dict, feat: Features, net: NetConfig, cfg,
                     recorder=None):
    """Price a lookup/scan phase: sequential READ chains per lane
    (see :func:`read_trace_from_stats` for the trace semantics)."""
    n = int(np.asarray(stats["active"], bool).sum())
    if n == 0:
        return dict(_empty_sim(0), mops=0.0)
    tr = read_trace_from_stats(stats, cfg)
    sim = simulate(tr, net, cfg.n_ms, feat.onchip, recorder=recorder)
    sim["mops"] = n / sim["makespan_s"] / 1e6 if sim["makespan_s"] else 0.0
    return sim


def price_merged_phase(traces: list[V.VerbTrace], feat: Features,
                       net: NetConfig, cfg,
                       clock: ServerClock | None = None,
                       recorder=None):
    """Price one cluster wave: merge per-CS traces into one timeline and
    replay it against the *shared* per-MS resources.

    Returns ``(sim, merged)``: the usual :func:`simulate` totals (per
    merged lane latency, makespan, verb/byte/doorbell counts) plus the
    merged trace itself so the caller can attribute lanes back to their
    source CS via ``merged.meta['lane_cs']``.  Cross-CS GLT serialization
    and NIC/atomic-unit queueing are emergent — see
    :func:`repro.core.verbs.merge_traces`.  ``clock`` (open-loop serving
    plane) replays the wave on the carried absolute timeline instead of
    a fresh one.
    """
    merged = V.merge_traces(traces)
    sim = simulate(merged, net, cfg.n_ms, feat.onchip, clock=clock,
                   recorder=recorder)
    return sim, merged


def price_maintenance(node_reads: int, small_reads: int, feat: Features,
                      net: NetConfig, cfg, rows_ms=None, recorder=None):
    """Price the CS cache's background traffic (image fills + version
    sweeps) by replaying its MAINT/SYNC read verbs."""
    tr = V.maintenance_trace(node_reads, small_reads, cfg.n_ms,
                             cfg.node_bytes, net.small_io_bytes,
                             rows_ms=rows_ms)
    return simulate(tr, net, cfg.n_ms, feat.onchip, recorder=recorder)


# The closed-form counter pricing that used to live here (per-feature RTT
# constants such as ``write_rtts = 1 if feat.combine else 2``) was replaced
# by the verb-trace plane above; the byte-counting ``IndexCacheSim`` stub
# before it lives on as the functional cache in :mod:`repro.core.cache`.
