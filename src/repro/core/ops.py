"""Batched read-path operations: traversal, lookup, range query.

Reads are lock-free (paper §4.2.2): a reader fetches node images via
"one-sided" gathers and validates them with the two-level version protocol of
Fig. 9 — node-level versions (FNV/RNV) guard whole-node consistency,
entry-level versions (FEV/REV) guard each key/value pair.  In the
phase-synchronous batched execution the snapshot is always consistent; the
protocol is still executed faithfully so that the contention simulator (which
interleaves torn write images) exercises the retry path.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.tree import (EMPTY_KEY, NULL_PTR, TreeConfig, TreeState)


class TraceB(NamedTuple):
    """Traversal result: target nodes plus the visited path (for parent
    lookup during splits and for netsim cache accounting)."""
    leaf: jax.Array          # [B] node id at stop level
    path: jax.Array          # [max_height, B] node ids visited (may repeat)
    path_level: jax.Array    # [max_height, B] level of each visited node
    hops: jax.Array          # [B] number of distinct descents (netsim)


def _descend_once(st: TreeState, node: jax.Array, qkeys: jax.Array,
                  stop_level: jax.Array, chase_hops: int) -> jax.Array:
    """One traversal step: bounded B-link sibling chase, then one descent."""
    # --- sibling chase (paper §4.2.1): key beyond the fence => go right ---
    for _ in range(chase_hops):
        fh = st.fence_hi[node]
        sib = st.sibling[node]
        chase = (qkeys >= fh) & (sib != NULL_PTR)
        node = jnp.where(chase, sib, node)
    lv = st.level[node].astype(jnp.int32)
    nk = st.keys[node]                       # [B, F]
    nv = st.vals[node]
    valid = nk != EMPTY_KEY
    le = valid & (nk <= qkeys[:, None])
    j = jnp.maximum(jnp.sum(le.astype(jnp.int32), axis=1) - 1, 0)
    child = jnp.take_along_axis(nv, j[:, None], axis=1)[:, 0]
    return jnp.where(lv > stop_level, child, node)


def traverse(cfg: TreeConfig, st: TreeState, qkeys: jax.Array,
             stop_level: int = 0, start: jax.Array | None = None,
             stop_level_arr: jax.Array | None = None,
             chase_hops: int = 2) -> TraceB:
    """Route each query key to its node at ``stop_level`` (0 = leaf).

    ``stop_level_arr`` gives a per-lane stop level (used by the split-repair
    cascade, where each pending separator targets a different level).
    """
    b = qkeys.shape[0]
    node0 = jnp.broadcast_to(st.root, (b,)).astype(jnp.int32)
    if start is not None:
        node0 = jnp.where(start != NULL_PTR, start, node0)
    stop = (jnp.full((b,), stop_level, jnp.int32)
            if stop_level_arr is None else stop_level_arr.astype(jnp.int32))

    def body(node, _):
        nxt = _descend_once(st, node, qkeys, stop, chase_hops)
        return nxt, (node, st.level[node].astype(jnp.int32))

    final, (path, plevel) = lax.scan(body, node0, None, length=cfg.max_height)
    hops = 1 + jnp.sum((path[1:] != path[:-1]).astype(jnp.int32), axis=0)
    return TraceB(leaf=final, path=path, path_level=plevel, hops=hops)


def parent_at_level(trace: TraceB, level: jax.Array | int) -> jax.Array:
    """Node visited at ``level`` on each lane's path (NULL if none)."""
    hit = trace.path_level == level
    cand = jnp.where(hit, trace.path, NULL_PTR)
    return jnp.max(cand, axis=0)


class LookupResult(NamedTuple):
    value: jax.Array         # [B] int32 (NULL_PTR when absent)
    found: jax.Array         # [B] bool
    consistent: jax.Array    # [B] bool — two-level version check passed
    leaf: jax.Array          # [B] leaf visited (netsim / cache accounting)
    hops: jax.Array          # [B] descents (netsim)


def leaf_lookup(st: TreeState, leaf: jax.Array, qkeys: jax.Array
                ) -> LookupResult:
    """Search leaf images for ``qkeys`` with the Fig. 9 version protocol.

    The unsorted leaf layout (paper §4.4) forces a full-node scan — the VPU
    analogue of the paper's "traverse the entire targeted leaf node".
    """
    nk = st.keys[leaf]                       # [B, F] snapshot
    nv = st.vals[leaf]
    fev = st.fev[leaf]
    rev = st.rev[leaf]
    node_ok = (st.fnv[leaf] == st.rnv[leaf]) & ~st.free_bit[leaf]

    eq = nk == qkeys[:, None]                # unsorted: compare every slot
    found = jnp.any(eq, axis=1)
    slot = jnp.argmax(eq, axis=1)
    take = lambda a: jnp.take_along_axis(a, slot[:, None], axis=1)[:, 0]
    entry_ok = take(fev) == take(rev)
    value = jnp.where(found, take(nv), NULL_PTR)
    consistent = node_ok & (entry_ok | ~found)
    return LookupResult(value=value, found=found & consistent,
                        consistent=consistent, leaf=leaf,
                        hops=jnp.zeros_like(leaf))


def lookup_batch(cfg: TreeConfig, st: TreeState, qkeys: jax.Array,
                 cache_image: dict | None = None, chase_hops: int = 4,
                 kernel_mode: str = "ref") -> LookupResult:
    """Batched point lookup.

    With a ``cache_image`` (see :mod:`repro.core.cache`) the descent runs
    locally through the replicated CS cache and ``hops`` reports the
    *remote* reads a real CS would issue (1 on a clean hit); without one
    it is the plain root-to-leaf traversal.
    """
    if cache_image is not None:
        from repro.core.cache import cached_lookup
        res, _ = cached_lookup(cfg, st, cache_image, qkeys,
                               chase_hops=chase_hops,
                               kernel_mode=kernel_mode)
        return res
    tr = traverse(cfg, st, qkeys)
    res = leaf_lookup(st, tr.leaf, qkeys)
    return res._replace(hops=tr.hops)


class RangeResult(NamedTuple):
    keys: jax.Array          # [B, count] int32 (EMPTY_KEY padding)
    vals: jax.Array          # [B, count]
    n: jax.Array             # [B] number of valid results
    leaves_read: jax.Array   # [B] leaves fetched (netsim)
    consistent: jax.Array    # [B] bool
    start_hit: jax.Array     # [B] bool — initial descent was a cache hit
    start_leaf: jax.Array    # [B] first leaf of the scan (verb-plane MS
                             #    targeting for the sibling-chain reads)


def range_batch(cfg: TreeConfig, st: TreeState, lo: jax.Array, count: int,
                max_leaves: int,
                cache_image: dict | None = None) -> RangeResult:
    """Fetch the first ``count`` pairs with key >= lo for each lane.

    Mirrors the paper §4.4: the client issues parallel RDMA_READs along the
    sibling chain and version-checks each leaf like a lookup.  With a
    ``cache_image`` the initial descent runs through the CS cache
    (``start_hit``); a stale start leaf is harmless — the sibling chain
    walks right past it, exactly the B-link argument.
    """
    b = lo.shape[0]
    tr = traverse(cfg, st, lo)
    start = tr.leaf
    start_hit = jnp.zeros((b,), bool)
    if cache_image is not None:
        from repro.core.cache import descend_image, leaf_sound
        leaf0, hit, _ = descend_image(cache_image, lo, cfg.max_height)
        sound = hit & leaf_sound(st, leaf0, lo)   # a split start falls back
        start = jnp.where(sound, leaf0, start)
        start_hit = sound
    tr = tr._replace(leaf=start)

    def chain(leaf, _):
        nxt = st.sibling[leaf]
        return jnp.where(nxt != NULL_PTR, nxt, leaf), leaf

    _, leaves = lax.scan(chain, tr.leaf, None, length=max_leaves)
    leaves = jnp.swapaxes(leaves, 0, 1)              # [B, max_leaves]
    # dedupe the tail (sibling chain may saturate at the rightmost leaf)
    dup = jnp.concatenate(
        [jnp.zeros((b, 1), bool), leaves[:, 1:] == leaves[:, :-1]], axis=1)

    nk = st.keys[leaves]                             # [B, L, F]
    nv = st.vals[leaves]
    node_ok = (st.fnv[leaves] == st.rnv[leaves]) & ~st.free_bit[leaves]
    entry_ok = st.fev[leaves] == st.rev[leaves]
    valid = ((nk != EMPTY_KEY) & (nk >= lo[:, None, None])
             & entry_ok & node_ok[:, :, None] & ~dup[:, :, None])
    f = cfg.fanout
    flat = (b, leaves.shape[1] * f)      # explicit: survives empty batches
    flat_k = jnp.where(valid, nk, jnp.int32(2**31 - 1)).reshape(flat)
    flat_v = nv.reshape(flat)
    order = jnp.argsort(flat_k, axis=1)
    sk = jnp.take_along_axis(flat_k, order[:, :count], axis=1)
    sv = jnp.take_along_axis(flat_v, order[:, :count], axis=1)
    got = sk != jnp.int32(2**31 - 1)
    return RangeResult(
        keys=jnp.where(got, sk, EMPTY_KEY),
        vals=jnp.where(got, sv, NULL_PTR),
        n=jnp.sum(got.astype(jnp.int32), axis=1),
        leaves_read=jnp.sum((~dup).astype(jnp.int32), axis=1),
        consistent=jnp.all(node_ok | dup, axis=1),
        start_hit=start_hit,
        start_leaf=tr.leaf,
    )
