"""Pure-python oracle for the Sherman index.

Semantics of a batched phase (the SIMD adaptation documented in DESIGN.md §8):
ops within one batch are applied in *lane order* — lane i "arrives" before
lane i+1.  The oracle is a sorted mapping with exactly those semantics, used
by unit and hypothesis tests to validate every batched tree operation.
"""
from __future__ import annotations

import bisect
from typing import Iterable, Optional


class OracleIndex:
    def __init__(self) -> None:
        self._keys: list[int] = []
        self._vals: dict[int, int] = {}

    # -- write ops ---------------------------------------------------------
    def insert(self, key: int, value: int) -> None:
        """Insert or update (the paper folds updates into 'insert')."""
        if key not in self._vals:
            bisect.insort(self._keys, key)
        self._vals[key] = value

    def delete(self, key: int) -> None:
        if key in self._vals:
            del self._vals[key]
            i = bisect.bisect_left(self._keys, key)
            del self._keys[i]

    def insert_batch(self, keys: Iterable[int], vals: Iterable[int]) -> None:
        for k, v in zip(keys, vals):
            self.insert(int(k), int(v))

    def delete_batch(self, keys: Iterable[int]) -> None:
        for k in keys:
            self.delete(int(k))

    # -- read ops ----------------------------------------------------------
    def lookup(self, key: int) -> Optional[int]:
        return self._vals.get(int(key))

    def range(self, lo: int, count: int) -> list[tuple[int, int]]:
        """First ``count`` pairs with key >= lo, in key order."""
        i = bisect.bisect_left(self._keys, lo)
        out = []
        for k in self._keys[i:i + count]:
            out.append((k, self._vals[k]))
        return out

    def __len__(self) -> int:
        return len(self._keys)

    def items(self) -> list[tuple[int, int]]:
        return [(k, self._vals[k]) for k in self._keys]
