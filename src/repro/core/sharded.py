"""Distributed execution of the Sherman index on a device mesh.

Two execution paths, mirroring the §Perf story:

* **pjit path (baseline)** — the single-pool phase functions are jitted with
  the node pool sharded over the ``model`` ("mem") axis and the op batch
  sharded over ``data``.  XLA SPMD auto-partitions the gathers/scatters;
  correct everywhere (including splits) but generates all-gather-heavy HLO.

* **routed path (optimized)** — a shard_map program that emulates one-sided
  verbs: a *remote row read* is an all_gather of row requests over the mem
  axis followed by a psum of owner responses (each row served by exactly one
  owner).  Entry-granular writes are routed the same way and applied locally
  by the owner — the collective analogue of RDMA_WRITE.  The CS-side cache
  (paper §4.2.3) is a small replicated image of the top two tree levels, so
  a cache-hit lookup costs exactly one remote read, like the paper.

Structural changes (splits) always run through the pjit path — they are the
paper's rare (≈0.4 %) slow path and reuse the verified single-pool code.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import write as W
from repro.core.ops import leaf_lookup
from repro.core.tree import NULL_PTR, TreeConfig, TreeState

MEM_AXIS = "model"       # the mem pool shards over the TP/model axis
DATA_AXIS = "data"

# jax.shard_map landed after 0.4.x (older versions expose it under
# jax.experimental.shard_map) and its replication-check kwarg was renamed
# check_rep -> check_vma along the way, so probe the signature, not the
# version.
def _shard_map_compat():
    import inspect
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    try:
        params = inspect.signature(sm).parameters
    except (TypeError, ValueError):
        params = {}
    for kw in ("check_vma", "check_rep"):
        if kw in params:
            return sm, {kw: False}
    return sm, {}


_shard_map, _SHARD_MAP_KW = _shard_map_compat()


def tree_pspecs(cfg: TreeConfig) -> TreeState:
    """PartitionSpecs: pool rows over the mem axis, lock tables likewise."""
    row = P(MEM_AXIS)
    return TreeState(
        keys=row, vals=row, fev=row, rev=row, fnv=row, rnv=row,
        level=row, fence_lo=row, fence_hi=row, sibling=row, free_bit=row,
        glt=P(MEM_AXIS, None), root=P(), height=P(),
        alloc_next=P(MEM_AXIS), alloc_rr=P(),
    )


def shard_tree(st: TreeState, mesh: Mesh, cfg: TreeConfig) -> TreeState:
    specs = tree_pspecs(cfg)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), st, specs)


# --------------------------------------------------------------------------
# routed one-sided primitives (inside shard_map over (DATA_AXIS, MEM_AXIS))
# --------------------------------------------------------------------------

def _remote_read_rows(cfg: TreeConfig, local: TreeState, rows: jax.Array):
    """Read arbitrary global pool rows from their owning mem shards.

    ``local`` holds this device's row block [N/n_ms, ...]; ``rows`` (global
    ids) is replicated over the mem axis, so each owner serves its rows and
    a single psum combines the unique responses — the collective analogue
    of a one-sided RDMA_READ (one "round trip").
    """
    me = lax.axis_index(MEM_AXIS)
    owner = rows // cfg.nodes_per_ms
    local_idx = jnp.where(owner == me, rows % cfg.nodes_per_ms, 0)
    mine = owner == me

    def serve(arr):
        got = arr[local_idx]
        m = mine.reshape(mine.shape + (1,) * (got.ndim - 1))
        return lax.psum(jnp.where(m, got, jnp.zeros_like(got)), MEM_AXIS)

    return dict(
        keys=serve(local.keys), vals=serve(local.vals),
        fev=serve(local.fev), rev=serve(local.rev),
        fnv=serve(local.fnv), rnv=serve(local.rnv),
        level=serve(local.level.astype(jnp.int32)),
        free=serve(local.free_bit.astype(jnp.int8)).astype(bool))


class RoutedLookupResult(NamedTuple):
    value: jax.Array
    found: jax.Array
    consistent: jax.Array
    leaf: jax.Array


def _routed_lookup_body(cfg: TreeConfig, st_local: TreeState, cache: dict,
                        qkeys: jax.Array, depth: int) -> RoutedLookupResult:
    """Per-(data,mem)-device body: traverse the replicated cache image, then
    one routed remote read of the target leaves (the paper's cache-hit
    fast path: a single RDMA_READ)."""
    # --- cache traversal (replicated, no communication) ---
    from repro.core.cache import descend_image
    # miss lanes resume from the frontier (first uncached node on the path)
    node, hit, _ = descend_image(cache, qkeys, max(depth, cfg.max_height))

    # --- remote leaf read: all_gather requests + psum responses ---
    img = _remote_read_rows(cfg, st_local, node)
    nk, nv = img["keys"], img["vals"]
    eq = nk == qkeys[:, None]
    found = jnp.any(eq, axis=1)
    slot = jnp.argmax(eq, axis=1)
    take = lambda a: jnp.take_along_axis(a, slot[:, None], axis=1)[:, 0]
    # a fetched non-leaf (cache too shallow / evicted level-1 node) must
    # not answer: its separators alias real keys and its "values" are
    # child pointers
    node_ok = (img["fnv"] == img["rnv"]) & ~img["free"] & \
        (img["level"] == 0)
    entry_ok = take(img["fev"]) == take(img["rev"])
    consistent = node_ok & (entry_ok | ~found)
    value = jnp.where(found & consistent, take(nv), NULL_PTR)
    return RoutedLookupResult(value=value, found=found & consistent,
                              consistent=consistent, leaf=node)


def build_cache(cfg: TreeConfig, st: TreeState, depth: int = 2,
                max_rows: int | None = None) -> dict:
    """Replicated CS-side image of the top ``depth`` tree levels — a thin
    wrapper over :func:`repro.core.cache.fill_image`, the single source of
    image construction (paper §4.2.3)."""
    from repro.core.cache import fill_image
    if max_rows is None:
        max_rows = 1 + cfg.fanout ** (depth - 1) + cfg.fanout ** depth
    image, _ = fill_image(cfg, st, levels=depth, max_rows=max_rows)
    return image


def routed_lookup_fn(cfg: TreeConfig, mesh: Mesh, depth: int = 2):
    """Build the shard_map'd routed lookup: keys sharded over data, pool
    sharded over mem, cache replicated."""
    specs = tree_pspecs(cfg)
    cache_specs = dict(rows=P(), keys=P(), vals=P(), level=P(), valid=P(),
                       fnv=P(), root=P())

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(specs, cache_specs, P(DATA_AXIS)),
        out_specs=RoutedLookupResult(P(DATA_AXIS), P(DATA_AXIS),
                                     P(DATA_AXIS), P(DATA_AXIS)),
        **_SHARD_MAP_KW)
    def fn(st_local, cache, qkeys):
        # responses are identical across the mem axis (psum-combined);
        # one copy per data shard survives
        return _routed_lookup_body(cfg, st_local, cache, qkeys, depth)

    return jax.jit(fn)


# --------------------------------------------------------------------------
# pjit path: the verified single-pool phase under SPMD auto-partitioning
# --------------------------------------------------------------------------

def pjit_phase_fns(cfg: TreeConfig, mesh: Mesh):
    """jit the single-pool write phase with sharded state (baseline path)."""
    specs = tree_pspecs(cfg)
    s = lambda p: NamedSharding(mesh, p)
    st_sh = jax.tree_util.tree_map(s, specs)
    b_sh = s(P(DATA_AXIS))
    rep = s(P())
    rq_sh = W.RepairQueue(sep=b_sh, child=b_sh, level=b_sh, valid=b_sh)

    wp = jax.jit(
        functools.partial(W.write_phase, cfg),
        in_shardings=(st_sh, b_sh, b_sh, b_sh, b_sh, b_sh, rq_sh),
        donate_argnums=(0,))
    return wp
