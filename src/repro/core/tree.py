"""Sherman tree state: a disaggregated node pool as a JAX pytree.

The disaggregated memory pool of the paper (a set of memory servers, each
exposing registered memory regions) is modelled as a struct-of-arrays node
pool.  Row ``i`` of every array is one tree node; the owning memory server is
``i // nodes_per_ms`` (contiguous blocks, so the pool shards cleanly over the
"mem" mesh axis in :mod:`repro.core.sharded`).

Pointers follow the paper's 64-bit = 16-bit MS id + 48-bit address split —
here a pointer is simply the global row index (int32), from which the MS id
is derived.  ``NULL_PTR`` (-1) is the null pointer.

Node layout (paper Fig. 8):

* leaf:      FNV | [FEV, key, value, REV] * fanout | RNV   (entries UNSORTED)
* internal:  FNV | [key, child] * fanout | RNV             (entries SORTED)

Internal nodes use the *separator* representation: entry ``(k_j, c_j)`` means
child ``c_j`` covers keys in ``[k_j, k_{j+1})``; the first separator equals
the node's lower fence.  Every node carries fence keys and its level so that
readers can detect stale cache entries / freed nodes (paper §4.2.3/§4.2.4).

The global lock table (GLT) — the paper's NIC on-chip lock array — is a small
``uint16`` array per MS (131072 locks by default, 16-bit thanks to masked
CAS), kept separate from the node pool.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

NULL_PTR = jnp.int32(-1)
EMPTY_KEY = jnp.int32(-1)          # slot never used / deleted ("null" key)
KEY_MIN = -(2**31) + 2             # lower fence of leftmost nodes
KEY_MAX = 2**31 - 1                # upper fence of rightmost nodes
VERSION_MOD = 16                   # 4-bit versions


@dataclasses.dataclass(frozen=True)
class TreeConfig:
    """Static configuration of a Sherman tree."""

    n_ms: int = 4                  # number of memory servers (mem shards)
    nodes_per_ms: int = 4096       # node-pool rows per MS
    fanout: int = 16               # entries per node (leaf and internal)
    n_locks_per_ms: int = 131072   # GLT entries per MS (paper: 256KB/16bit)
    max_height: int = 6            # static traversal bound
    handover_max: int = 4          # MAX_DEPTH consecutive lock handovers
    n_cs: int = 4                  # compute servers (data shards)
    # Modeled wire sizes (bytes) for netsim accounting; defaults follow the
    # paper's 1KB nodes with 8B keys / 8B values and 4-bit paired versions.
    key_bytes: int = 8
    value_bytes: int = 8

    @property
    def n_nodes(self) -> int:
        return self.n_ms * self.nodes_per_ms

    @property
    def park_row(self) -> int:
        """Reserved row used as the scatter target of masked-out lanes.

        The last row of every MS is reserved (never allocated) so that
        masked scatters can always be parked on a row that carries no live
        node, on every mem shard.  ``park_row`` is the global instance."""
        return self.n_nodes - 1

    @property
    def alloc_cap(self) -> int:
        """Allocatable rows per MS (last row reserved for parking)."""
        return self.nodes_per_ms - 1

    @property
    def entry_bytes(self) -> int:
        # key + value + FEV/REV pair (1 byte total) — the paper's 17B.
        return self.key_bytes + self.value_bytes + 1

    @property
    def node_bytes(self) -> int:
        # header: FNV/RNV (1B), fences (2 keys), level+free+sibling (10B)
        return self.fanout * self.entry_bytes + 2 * self.key_bytes + 11

    def ms_of(self, node_id):
        return node_id // self.nodes_per_ms

    def lock_index(self, node_id):
        """Hash a node address into its MS's global lock table (paper l.5)."""
        return node_id % self.n_locks_per_ms


class TreeState(NamedTuple):
    """The disaggregated tree: one pytree, shardable over the mem axis."""

    keys: jax.Array          # [N, F] int32; EMPTY_KEY = empty slot
    vals: jax.Array          # [N, F] int32; leaf: value, internal: child ptr
    fev: jax.Array           # [N, F] uint8 front entry versions (4-bit)
    rev: jax.Array           # [N, F] uint8 rear  entry versions (4-bit)
    fnv: jax.Array           # [N]    uint8 front node version
    rnv: jax.Array           # [N]    uint8 rear  node version
    level: jax.Array         # [N]    int8  (0 = leaf, -1 = unallocated)
    fence_lo: jax.Array      # [N]    int32 inclusive lower fence
    fence_hi: jax.Array      # [N]    int32 exclusive upper fence
    sibling: jax.Array       # [N]    int32 right sibling (B-link), NULL_PTR
    free_bit: jax.Array      # [N]    bool  True = node freed (paper §4.2.4)
    glt: jax.Array           # [n_ms, n_locks] uint16 global lock tables
    root: jax.Array          # []     int32
    height: jax.Array        # []     int32 (#levels; 1 = root is a leaf)
    alloc_next: jax.Array    # [n_ms] int32 per-MS bump pointer
    alloc_rr: jax.Array      # []     int32 round-robin MS cursor


def empty_state(cfg: TreeConfig) -> TreeState:
    n, f = cfg.n_nodes, cfg.fanout
    return TreeState(
        keys=jnp.full((n, f), EMPTY_KEY, jnp.int32),
        vals=jnp.full((n, f), NULL_PTR, jnp.int32),
        fev=jnp.zeros((n, f), jnp.uint8),
        rev=jnp.zeros((n, f), jnp.uint8),
        fnv=jnp.zeros((n,), jnp.uint8),
        rnv=jnp.zeros((n,), jnp.uint8),
        level=jnp.full((n,), -1, jnp.int8),
        fence_lo=jnp.zeros((n,), jnp.int32),
        fence_hi=jnp.zeros((n,), jnp.int32),
        sibling=jnp.full((n,), NULL_PTR, jnp.int32),
        free_bit=jnp.zeros((n,), bool),
        glt=jnp.zeros((cfg.n_ms, cfg.n_locks_per_ms), jnp.uint16),
        root=jnp.int32(0),
        height=jnp.int32(0),
        alloc_next=jnp.zeros((cfg.n_ms,), jnp.int32),
        alloc_rr=jnp.int32(0),
    )


def bulkload(cfg: TreeConfig, keys: np.ndarray, vals: np.ndarray,
             fill: float = 0.8) -> TreeState:
    """Build a tree from sorted unique keys, each leaf ``fill`` full.

    Host-side (numpy) setup, mirroring the paper's bulkload of 1B entries 80%
    full before each benchmark.  Leaves are written *sorted* here — unsortedness
    only arises from subsequent inserts, which is also true of the paper.
    """
    keys = np.asarray(keys, np.int32)
    vals = np.asarray(vals, np.int32)
    order = np.argsort(keys, kind="stable")
    keys, vals = keys[order], vals[order]
    assert keys.ndim == 1 and keys.shape == vals.shape
    if keys.size and np.any(keys[1:] == keys[:-1]):
        raise ValueError("bulkload requires unique keys")

    f = cfg.fanout
    per_leaf = max(1, min(f, int(round(f * fill))))
    n, _ = cfg.n_nodes, cfg.fanout

    st = jax.tree_util.tree_map(np.asarray, empty_state(cfg))
    st = TreeState(*[np.array(x) for x in st])

    next_row = np.zeros(cfg.n_ms, np.int64)
    rr = [0]

    def alloc() -> int:
        # two-stage allocator: round-robin MS choice + per-MS bump pointer
        for _ in range(cfg.n_ms):
            ms = rr[0] % cfg.n_ms
            rr[0] += 1
            if next_row[ms] < cfg.alloc_cap:
                row = ms * cfg.nodes_per_ms + int(next_row[ms])
                next_row[ms] += 1
                return row
        raise RuntimeError("node pool exhausted during bulkload")

    # ---- build leaf level ----
    level_nodes: list[int] = []     # node ids of current level, left→right
    level_keys: list[int] = []      # lower fence of each node
    if keys.size == 0:
        nid = alloc()
        st.level[nid] = 0
        st.fence_lo[nid], st.fence_hi[nid] = KEY_MIN, KEY_MAX
        level_nodes, level_keys = [nid], [KEY_MIN]
    else:
        starts = list(range(0, keys.size, per_leaf))
        for j, s in enumerate(starts):
            chunk = slice(s, min(s + per_leaf, keys.size))
            nid = alloc()
            cnt = keys[chunk].size
            st.keys[nid, :cnt] = keys[chunk]
            st.vals[nid, :cnt] = vals[chunk]
            st.level[nid] = 0
            st.fence_lo[nid] = KEY_MIN if j == 0 else int(keys[s])
            st.fence_hi[nid] = (KEY_MAX if j == len(starts) - 1
                                else int(keys[starts[j + 1]]))
            if level_nodes:
                st.sibling[level_nodes[-1]] = nid
            level_nodes.append(nid)
            level_keys.append(int(st.fence_lo[nid]))

    # ---- build internal levels bottom-up ----
    lvl = 0
    while len(level_nodes) > 1:
        lvl += 1
        parents, parent_keys = [], []
        for s in range(0, len(level_nodes), f):
            group = level_nodes[s:s + f]
            gkeys = level_keys[s:s + f]
            nid = alloc()
            st.keys[nid, :len(group)] = gkeys
            st.vals[nid, :len(group)] = group
            st.level[nid] = lvl
            st.fence_lo[nid] = gkeys[0] if s else KEY_MIN
            parents.append(nid)
            parent_keys.append(KEY_MIN if s == 0 else gkeys[0])
        for j, nid in enumerate(parents):
            st.fence_hi[nid] = (KEY_MAX if j == len(parents) - 1
                                else parent_keys[j + 1])
            if j + 1 < len(parents):
                st.sibling[nid] = parents[j + 1]
        # first separator of each internal node must equal its lower fence
        for nid in parents:
            st.keys[nid, 0] = st.fence_lo[nid]
        level_nodes, level_keys = parents, parent_keys

    root = level_nodes[0]
    out = TreeState(
        keys=jnp.asarray(st.keys), vals=jnp.asarray(st.vals),
        fev=jnp.asarray(st.fev), rev=jnp.asarray(st.rev),
        fnv=jnp.asarray(st.fnv), rnv=jnp.asarray(st.rnv),
        level=jnp.asarray(st.level),
        fence_lo=jnp.asarray(st.fence_lo), fence_hi=jnp.asarray(st.fence_hi),
        sibling=jnp.asarray(st.sibling), free_bit=jnp.asarray(st.free_bit),
        glt=jnp.asarray(st.glt),
        root=jnp.int32(root), height=jnp.int32(lvl + 1),
        alloc_next=jnp.asarray(next_row, jnp.int32), alloc_rr=jnp.int32(rr[0]),
    )
    return out
