"""RDMA verb traces: the structured interface between the two planes.

The functional plane (write path, read path, CS cache) reports *per-lane
structural arrays* for each phase — target leaf, conflict-group ranks,
split outputs, remote-read counts.  This module turns them into a
:class:`VerbTrace`: one record per RDMA verb a real CS would post, with

* ``kind``     — READ / WRITE / CAS (the one-sided verb),
* ``role``     — what the verb is for (taxonomy below),
* ``ms``       — target memory server,
* ``nbytes``   — payload bytes on the wire,
* ``lane``     — issuing client lane (-1 for background traffic),
* ``doorbell`` — posting group: verbs sharing an id ride one doorbell ring,
* ``dep``/``dep2`` — verbs whose *completion* gates this verb's posting,
* ``at``       — earliest client-side post time (used to stagger spin CAS),
* ``obj``      — target object of lock-plane verbs (the GLT entry's node
  row), so :func:`merge_traces` can serialize cross-CS lock conflicts.

``netsim.simulate`` replays a trace against per-MS resources; nothing in
the trace is priced here.

Verb taxonomy (docs/DESIGN.md §10):

==========  ====  ==========================================================
role        kind  meaning
==========  ====  ==========================================================
TRAVERSE    READ  node fetch on the descent to the leaf (sequential chain —
                  address-dependent, so never combinable, paper §4.5)
LOCK        CAS   remote lock acquisition on the leaf's MS
WRITEBACK   WRITE the op's data write-back to the leaf
SIBLING     WRITE new-sibling image write of a split
PARENT      WRITE separator insertion into the parent (B-link: may complete
                  after the unlock — the half-split/repair-queue semantics)
UNLOCK      WRITE lock release (small write to the GLT)
SPIN        CAS   failed lock attempt of a spinning waiter (no hierarchy)
MAINT       READ  whole-node read refilling the CS index-cache image
SYNC        READ  small version read of a cache coherence sweep
==========  ====  ==========================================================

Feature toggles are *trace transformations* over the canonical stream
(which is the FG+ discipline: every lane CASes, spins while waiting, and
releases remotely; whole-node write-backs; one doorbell per verb):

* :func:`hierarchical_locks`  — HOCL (§4.3): only handover-cycle heads
  issue the LOCK CAS, only chain ends issue the UNLOCK, spinning
  disappears; waiters are gated on their queue predecessor instead.
* :func:`twolevel_writes`     — two-level versions (§4.4): non-split
  write-backs shrink to ``entry_bytes``.
* :func:`combine_doorbells`   — command combination (§4.5): the UNLOCK
  (and, for a same-MS sibling, the SIBLING write — the three-way split
  combination) joins the WRITEBACK's doorbell: posted together, ordered
  by in-order delivery, no extra round trip.

``onchip`` is not a transformation — it is a resource parameter of the
event loop (atomic-unit service time).
"""
from __future__ import annotations

import dataclasses

import numpy as np

READ, WRITE, CAS = 0, 1, 2
(TRAVERSE, LOCK, WRITEBACK, SIBLING, PARENT, UNLOCK, SPIN, MAINT,
 SYNC) = range(9)

ROLE_NAMES = ("traverse", "lock", "writeback", "sibling", "parent",
              "unlock", "spin", "maint", "sync")

LOCK_BYTES = 16          # lock CAS / release payload (GLT entry + header)


@dataclasses.dataclass
class VerbTrace:
    """One phase's RDMA verb stream (struct-of-arrays, numpy)."""

    kind: np.ndarray       # [V] int8   READ/WRITE/CAS
    role: np.ndarray       # [V] int8   taxonomy above
    ms: np.ndarray         # [V] int32  target memory server
    nbytes: np.ndarray     # [V] int64  wire payload
    lane: np.ndarray       # [V] int32  issuing lane (-1 = background)
    doorbell: np.ndarray   # [V] int64  posting group id (= head verb index)
    dep: np.ndarray        # [V] int64  gating verb index (-1 = none)
    dep2: np.ndarray       # [V] int64  second gate (cross-lane lock chain)
    at: np.ndarray         # [V] float  earliest client post time
    obj: np.ndarray | None = None  # [V] int64 target object (GLT lock row for
    #    lock-plane verbs, -1/None elsewhere) — lets merge_traces serialize
    #    cross-CS lock conflicts on the shared GLT entry
    n_lanes: int = 0
    meta: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def n_verbs(self) -> int:
        return int(self.kind.shape[0])

    @property
    def n_cas(self) -> int:
        return int((self.kind == CAS).sum())

    @property
    def total_bytes(self) -> float:
        return float(self.nbytes.sum())

    @property
    def doorbell_heads(self) -> np.ndarray:
        """Mask of verbs that ring their own doorbell (posting events)."""
        return self.doorbell == np.arange(self.n_verbs)

    @property
    def n_doorbells(self) -> int:
        return int(self.doorbell_heads.sum())

    def per_lane_write_bytes(self) -> np.ndarray:
        """Data-plane bytes written back per lane (WRITEBACK + SIBLING —
        the §5.5.3 'write size' metric; lock-plane writes excluded)."""
        m = ((self.role == WRITEBACK) | (self.role == SIBLING)) & \
            (self.lane >= 0)
        out = np.zeros(self.n_lanes)
        np.add.at(out, self.lane[m], self.nbytes[m].astype(np.float64))
        return out

    def per_lane_doorbells(self, include_spin: bool = False) -> np.ndarray:
        """Doorbell rings per lane — the sequential posting-depth metric
        netsim reports as ``lane_doorbells`` (SPIN load excluded by
        default)."""
        m = self.doorbell_heads & (self.lane >= 0)
        if not include_spin:
            m &= self.role != SPIN
        return np.bincount(self.lane[m], minlength=self.n_lanes)


def _empty_trace(n_lanes: int = 0, meta: dict | None = None) -> VerbTrace:
    z = lambda dt: np.zeros(0, dt)
    return VerbTrace(kind=z(np.int8), role=z(np.int8), ms=z(np.int32),
                     nbytes=z(np.int64), lane=z(np.int32),
                     doorbell=z(np.int64), dep=z(np.int64), dep2=z(np.int64),
                     at=z(np.float64), obj=z(np.int64), n_lanes=n_lanes,
                     meta=meta or {})


def _chain_layout(R: np.ndarray, leaf_ms: np.ndarray, n_ms: int,
                  scan: bool = False):
    """Layout of per-lane sequential READ chains (``R[i]`` reads each).

    Returns ``(lane, ms, dep, last)`` with verb indices local to the
    chain segment (base 0): descents end at the leaf's MS walking
    backward round-robin; scans start there and walk right (siblings are
    round-robin allocated).  Shared by the write trace's TRAVERSE segment
    and the read-phase builder so the two stay in sync.
    """
    n = R.shape[0]
    nR = int(R.sum())
    lanes = np.arange(n, dtype=np.int64)
    roff = np.zeros(n + 1, np.int64)
    np.cumsum(R, out=roff[1:])
    rlane = np.repeat(lanes, R)
    j = np.arange(nR, dtype=np.int64) - roff[rlane]
    if scan:
        ms = (leaf_ms[rlane] + j) % n_ms
    else:
        ms = (leaf_ms[rlane] - (R[rlane] - 1 - j)) % n_ms
    dep = np.where(j > 0, np.arange(nR, dtype=np.int64) - 1, -1)
    return rlane, ms, dep, roff[1:] - 1


# --------------------------------------------------------------------------
# write-phase emission (canonical = FG+ lock discipline)
# --------------------------------------------------------------------------

def write_phase_trace(sd: dict, cfg, rtt_s: float) -> VerbTrace:
    """Canonical verb stream of one write phase.

    ``sd`` holds numpy views of :class:`repro.core.write.WriteStats`
    (see :func:`repro.core.api.write_stats_dict`).  The canonical stream
    is the no-hierarchy discipline — every lane CASes the lock, a lane at
    node rank *r* burns *r* failed SPIN attempts while waiting, every
    lane releases remotely — which :func:`hierarchical_locks` rewrites.
    """
    act = np.asarray(sd["active"], bool)
    n = int(act.sum())
    if n == 0:
        return _empty_trace()
    f = lambda k: np.asarray(sd[k])[act]
    leaf = f("leaf").astype(np.int64)
    height = max(int(sd["height"]), 1)
    cache_hit = f("cache_hit").astype(bool)
    node_rank = f("node_rank").astype(np.int64)
    split = f("split_lane").astype(bool)
    same_ms = f("split_same_ms").astype(bool) & split
    sib_row = f("split_new_row").astype(np.int64)
    leaf_ms = cfg.ms_of(leaf)
    sib_ms = np.where(split, cfg.ms_of(sib_row), leaf_ms)

    # node-chain predecessor: the lane one FIFO rank earlier on the leaf
    order = np.lexsort((node_rank, leaf))
    pred = np.full(n, -1, np.int64)
    same_leaf = leaf[order][1:] == leaf[order][:-1]
    pred[order[1:][same_leaf]] = order[:-1][same_leaf]

    meta = dict(
        n=n,
        read_cnt=np.where(cache_hit, 1, height).astype(np.int64),
        leaf_ms=leaf_ms.astype(np.int64), sib_ms=sib_ms.astype(np.int64),
        leaf_row=leaf,
        split=split, same_ms=same_ms, pred=pred,
        node_rank=node_rank,
        cycle_head=f("cycle_head").astype(bool),
        chain_end=f("chain_end").astype(bool),
        n_ms=int(cfg.n_ms), entry_bytes=int(cfg.entry_bytes),
        node_bytes=int(cfg.node_bytes), rtt_s=float(rtt_s),
    )
    return _assemble(meta,
                     cas_mask=np.ones(n, bool),
                     unlock_mask=np.ones(n, bool),
                     spin_cnt=node_rank)


def _assemble(meta: dict, cas_mask: np.ndarray, unlock_mask: np.ndarray,
              spin_cnt: np.ndarray) -> VerbTrace:
    """Lay out one write phase's verbs under a given lock discipline.

    Segment order (stable, relied on for same-ready-time FIFO ties in the
    event loop): TRAVERSE | LOCK | WRITEBACK | SIBLING | PARENT | UNLOCK
    | SPIN.
    """
    n = meta["n"]
    R = meta["read_cnt"]
    leaf_ms, sib_ms = meta["leaf_ms"], meta["sib_ms"]
    split, pred = meta["split"], meta["pred"]
    n_ms = meta["n_ms"]
    node_b, entry_b = meta["node_bytes"], meta["entry_bytes"]
    spin_cnt = np.where(cas_mask, spin_cnt, 0).astype(np.int64)

    nR, nC = int(R.sum()), int(cas_mask.sum())
    nS, nU, nSp = int(split.sum()), int(unlock_mask.sum()), int(
        spin_cnt.sum())
    total = nR + nC + n + 2 * nS + nU + nSp

    kind = np.empty(total, np.int8)
    role = np.empty(total, np.int8)
    ms = np.empty(total, np.int32)
    nbytes = np.empty(total, np.int64)
    lane = np.empty(total, np.int32)
    dep = np.full(total, -1, np.int64)
    dep2 = np.full(total, -1, np.int64)
    at = np.zeros(total, np.float64)
    obj = np.full(total, -1, np.int64)
    leaf_row = meta["leaf_row"].astype(np.int64)

    lanes = np.arange(n, dtype=np.int64)

    # -- TRAVERSE: per-lane sequential descent chains -----------------------
    rlane, rms, rdep, last_read = _chain_layout(R, leaf_ms, n_ms)
    sl = slice(0, nR)
    kind[sl], role[sl] = READ, TRAVERSE
    ms[sl] = rms                  # leaf read last, upper levels before it
    nbytes[sl], lane[sl] = node_b, rlane
    dep[sl] = rdep

    # -- index maps for the remaining segments ------------------------------
    cas_idx = np.full(n, -1, np.int64)
    cas_idx[cas_mask] = nR + np.arange(nC)
    wb_idx = nR + nC + lanes
    sib_idx = np.full(n, -1, np.int64)
    sib_idx[split] = nR + nC + n + np.arange(nS)
    par_idx = np.full(n, -1, np.int64)
    par_idx[split] = nR + nC + n + nS + np.arange(nS)
    ul_idx = np.full(n, -1, np.int64)
    ul_idx[unlock_mask] = nR + nC + n + 2 * nS + np.arange(nU)
    # the verb a queue successor waits on: the remote release if this lane
    # issues one, else its write-back (local handover)
    chain_end_verb = np.where(unlock_mask, ul_idx, wb_idx)
    pred_end = np.where(pred >= 0, chain_end_verb[np.maximum(pred, 0)], -1)

    # -- LOCK ---------------------------------------------------------------
    c = cas_idx[cas_mask]
    kind[c], role[c] = CAS, LOCK
    ms[c], nbytes[c], lane[c] = leaf_ms[cas_mask], LOCK_BYTES, \
        lanes[cas_mask]
    dep[c] = last_read[cas_mask]
    dep2[c] = pred_end[cas_mask]
    obj[c] = leaf_row[cas_mask]

    # -- WRITEBACK ----------------------------------------------------------
    w = wb_idx
    kind[w], role[w] = WRITE, WRITEBACK
    ms[w], nbytes[w], lane[w] = leaf_ms, node_b, lanes
    dep[w] = np.where(cas_mask, cas_idx, last_read)
    dep2[w] = np.where(cas_mask, -1, pred_end)     # handover hand-off gate

    # -- SIBLING / PARENT (split continuation) ------------------------------
    s = sib_idx[split]
    kind[s], role[s] = WRITE, SIBLING
    ms[s], nbytes[s], lane[s] = sib_ms[split], node_b, lanes[split]
    dep[s] = wb_idx[split]
    p = par_idx[split]
    kind[p], role[p] = WRITE, PARENT
    ms[p], nbytes[p], lane[p] = leaf_ms[split], entry_b, lanes[split]
    dep[p] = sib_idx[split]

    # -- UNLOCK -------------------------------------------------------------
    u = ul_idx[unlock_mask]
    kind[u], role[u] = WRITE, UNLOCK
    ms[u], nbytes[u], lane[u] = leaf_ms[unlock_mask], LOCK_BYTES, \
        lanes[unlock_mask]
    dep[u] = wb_idx[unlock_mask]
    obj[u] = leaf_row[unlock_mask]

    # -- SPIN: failed attempts of waiting lanes, one per RTT-spaced poll ----
    if nSp:
        sp = slice(total - nSp, total)
        splane = np.repeat(lanes, spin_cnt)
        soff = np.zeros(n + 1, np.int64)
        np.cumsum(spin_cnt, out=soff[1:])
        sj = np.arange(nSp, dtype=np.int64) - soff[splane]
        kind[sp], role[sp] = CAS, SPIN
        ms[sp], nbytes[sp], lane[sp] = leaf_ms[splane], LOCK_BYTES, splane
        obj[sp] = leaf_row[splane]
        at[sp] = (sj + 1) * meta["rtt_s"]

    meta = dict(meta, cas_mask=cas_mask, unlock_mask=unlock_mask,
                wb_idx=wb_idx, sib_idx=sib_idx, par_idx=par_idx,
                ul_idx=ul_idx, cas_idx=cas_idx)
    return VerbTrace(kind=kind, role=role, ms=ms, nbytes=nbytes, lane=lane,
                     doorbell=np.arange(total, dtype=np.int64), dep=dep,
                     dep2=dep2, at=at, obj=obj, n_lanes=n, meta=meta)


# --------------------------------------------------------------------------
# feature transformations
# --------------------------------------------------------------------------

def hierarchical_locks(tr: VerbTrace) -> VerbTrace:
    """HOCL rewrite (§4.3): reassemble the lock sub-stream so only
    handover-cycle heads CAS, only chain ends release, and nobody spins;
    waiters gate on their queue predecessor (the FIFO wait queue)."""
    m = tr.meta
    return _assemble(m, cas_mask=m["cycle_head"],
                     unlock_mask=m["chain_end"],
                     spin_cnt=np.zeros(m["n"], np.int64))


def twolevel_writes(tr: VerbTrace) -> VerbTrace:
    """Two-level versions (§4.4): a non-split write-back touches one
    entry (17 B), not the whole node."""
    m = tr.meta
    nbytes = tr.nbytes.copy()
    shrink = m["wb_idx"][~m["split"]]
    nbytes[shrink] = m["entry_bytes"]
    return dataclasses.replace(tr, nbytes=nbytes)


def combine_doorbells(tr: VerbTrace) -> VerbTrace:
    """Command combination (§4.5): merge dependent same-MS verbs into the
    write-back's doorbell — the UNLOCK always (the lock lives on the
    leaf's MS), and the SIBLING write when the sibling landed on the same
    MS (the three-way split combination).  Merged verbs inherit the
    head's gates, so they post together; per-MS in-order delivery keeps
    them correct."""
    m = tr.meta
    doorbell = tr.doorbell.copy()
    dep, dep2 = tr.dep.copy(), tr.dep2.copy()
    wb = m["wb_idx"]

    def merge(idx_of_lane, mask):
        tgt = idx_of_lane[mask]
        src = wb[mask]
        doorbell[tgt] = doorbell[src]
        dep[tgt], dep2[tgt] = dep[src], dep2[src]

    merge(m["ul_idx"], m["unlock_mask"])
    merge(m["sib_idx"], m["same_ms"] & (m["sib_idx"] >= 0))
    return dataclasses.replace(tr, doorbell=doorbell, dep=dep, dep2=dep2)


def shift_release(tr: VerbTrace, release_s, background_s: float = 0.0
                  ) -> VerbTrace:
    """Open-loop release gates: rebase a phase trace onto absolute time.

    ``release_s[lane]`` is the lane's op arrival (admission) timestamp on
    the serving plane's absolute timeline; every verb of the lane keeps
    its *relative* ``at`` floor (the spin-CAS RTT staggering) on top of
    it, so no verb of an op can start before the op arrived.  Background
    verbs (``lane == -1`` maintenance traffic) shift by ``background_s``
    — the wave's admission time.  This is a pure relabeling of *when*:
    verb structure, payloads, deps and doorbells are untouched, which is
    what keeps the t=0 open-loop run trace-identical to the closed-loop
    scheduler (tests/test_serve_queueing.py).
    """
    if tr.n_verbs == 0:
        return tr
    at = np.asarray(tr.at, np.float64).copy()
    lm = tr.lane >= 0
    if lm.any():
        at[lm] += np.asarray(release_s, np.float64)[tr.lane[lm]]
    if background_s and not lm.all():
        at[~lm] += float(background_s)
    return dataclasses.replace(tr, at=at)


# --------------------------------------------------------------------------
# read-phase / maintenance emission
# --------------------------------------------------------------------------

def read_phase_trace(reads: np.ndarray, leaf_ms: np.ndarray, n_ms: int,
                     node_bytes: int, scan: bool = False) -> VerbTrace:
    """Per-lane sequential READ chains for a lookup or scan phase.

    ``reads[i]`` is the lane's remote node reads (measured by the cache /
    traversal); lookups *end* at the leaf (descent), scans *start* at it
    (sibling chain, round-robin allocated rightward).  Reads are
    address-dependent, hence chained and never doorbell-combined."""
    n = reads.shape[0]
    if n == 0:
        return _empty_trace()
    R = np.maximum(reads.astype(np.int64), 1)
    nR = int(R.sum())
    rlane, ms, dep, _ = _chain_layout(R, leaf_ms, n_ms, scan=scan)
    return VerbTrace(
        kind=np.full(nR, READ, np.int8),
        role=np.full(nR, TRAVERSE, np.int8),
        ms=ms.astype(np.int32), nbytes=np.full(nR, node_bytes, np.int64),
        lane=rlane.astype(np.int32),
        doorbell=np.arange(nR, dtype=np.int64), dep=dep,
        dep2=np.full(nR, -1, np.int64), at=np.zeros(nR), n_lanes=n,
        meta=dict(read_cnt=R))


def maintenance_trace(node_reads: int, small_reads: int, n_ms: int,
                      node_bytes: int, small_bytes: int,
                      rows_ms: np.ndarray | None = None) -> VerbTrace:
    """Background cache traffic: MAINT whole-node image fills and SYNC
    version sweeps, independent parallel reads spread over the cached
    rows' owners (round-robin when the row set is unknown)."""
    total = node_reads + small_reads
    if total == 0:
        return _empty_trace()
    if rows_ms is None or rows_ms.size == 0:
        rows_ms = np.arange(max(n_ms, 1), dtype=np.int64)
    spread = lambda k: rows_ms[np.arange(k) % rows_ms.size]
    ms = np.concatenate([spread(node_reads), spread(small_reads)])
    return VerbTrace(
        kind=np.full(total, READ, np.int8),
        role=np.concatenate([np.full(node_reads, MAINT, np.int8),
                             np.full(small_reads, SYNC, np.int8)]),
        ms=ms.astype(np.int32),
        nbytes=np.concatenate(
            [np.full(node_reads, node_bytes, np.int64),
             np.full(small_reads, small_bytes, np.int64)]),
        lane=np.full(total, -1, np.int32),
        doorbell=np.arange(total, dtype=np.int64),
        dep=np.full(total, -1, np.int64), dep2=np.full(total, -1, np.int64),
        at=np.zeros(total), n_lanes=0, meta={})


# --------------------------------------------------------------------------
# multi-trace merge (the cluster plane's contention interface)
# --------------------------------------------------------------------------

def merge_traces(traces: list[VerbTrace],
                 glt_chain: bool = True) -> VerbTrace:
    """Merge per-CS verb traces into one concurrent timeline.

    Each input trace is one compute server's verb stream for the same
    scheduler round; the merged trace replays them against *shared* per-MS
    NIC and atomic-unit FIFOs (``netsim.simulate``), so cross-CS queueing
    delay falls out of the event loop instead of a closed-form formula.

    The merge is conservative by construction: verbs, bytes, CAS and
    doorbell rings are concatenated (indices/lanes offset per trace, -1
    sentinels preserved), never created or dropped — the conservation
    property the cluster tests pin.

    With ``glt_chain`` (default) the merge additionally serializes
    cross-CS lock conflicts on the shared GLT entry: the *entry* LOCK of
    trace *t* on object ``o`` (the one CAS per trace whose intra-CS
    ``dep2`` gate is free — its rank-0 lane) gains a gate on trace
    *t-1*'s last UNLOCK of ``o``.  Trace order is arrival order (the
    scheduler passes CSs in functional apply order), matching the
    functional plane's serialization.  Intra-CS chains (HOCL wait queues
    / spin storms) are already inside each trace.

    ``meta`` of the result carries ``lane_cs`` (source *position* of
    every merged lane in the caller's ``traces`` list — empty traces
    keep their position, so attribution survives CSs that sat a wave
    out) and ``src_verbs``/``src_lanes`` for attribution.
    """
    keep = [(i, t) for i, t in enumerate(traces) if t.n_verbs]
    if not keep:
        return _empty_trace()
    src, traces = [i for i, _ in keep], [t for _, t in keep]
    nv = np.array([t.n_verbs for t in traces], np.int64)
    nl = np.array([t.n_lanes for t in traces], np.int64)
    voff = np.concatenate([[0], np.cumsum(nv)[:-1]])
    loff = np.concatenate([[0], np.cumsum(nl)[:-1]])

    cat = np.concatenate
    shift = lambda cols, offs: cat(
        [np.where(c >= 0, c + o, -1) for c, o in zip(cols, offs)])
    objs = [t.obj if t.obj is not None
            else np.full(t.n_verbs, -1, np.int64) for t in traces]
    merged = VerbTrace(
        kind=cat([t.kind for t in traces]),
        role=cat([t.role for t in traces]),
        ms=cat([t.ms for t in traces]),
        nbytes=cat([t.nbytes for t in traces]),
        lane=shift([t.lane for t in traces], loff).astype(np.int32),
        doorbell=cat([t.doorbell + o for t, o in zip(traces, voff)]),
        dep=shift([t.dep for t in traces], voff),
        dep2=shift([t.dep2 for t in traces], voff),
        at=cat([t.at for t in traces]),
        obj=cat(objs),
        n_lanes=int(nl.sum()),
        meta=dict(lane_cs=np.repeat(np.asarray(src, np.int64), nl),
                  src_verbs=nv.tolist(), src_lanes=nl.tolist()))

    if glt_chain:
        dep2 = merged.dep2
        role, obj = merged.role, merged.obj
        last_unlock: dict[int, int] = {}
        for t, o in zip(traces, voff):
            sl = slice(int(o), int(o + t.n_verbs))
            entry = np.nonzero((role[sl] == LOCK) & (obj[sl] >= 0)
                               & (dep2[sl] < 0))[0] + int(o)
            for i in entry.tolist():
                prev = last_unlock.get(int(obj[i]), -1)
                if prev >= 0:
                    dep2[i] = prev
            rel = np.nonzero((role[sl] == UNLOCK) & (obj[sl] >= 0))[0] \
                + int(o)
            for i in rel.tolist():
                last_unlock[int(obj[i])] = i
        merged = dataclasses.replace(merged, dep2=dep2)
    return merged
