"""Batched write path: insert / update / delete with B-link node splits.

Faithful to the paper's Fig. 7 flow, adapted to phase-synchronous SIMD
execution (DESIGN.md §8):

* one batch ≡ one wave of concurrent client ops; lane order is arrival order;
* lock/contention structure is computed by :mod:`repro.core.hocl` and priced
  by netsim — data application itself is deterministic;
* without a split, an op touches exactly one entry and bumps its FEV/REV
  (17-byte write-back — the two-level-version win);
* splits sort the (unsorted) leaf, move the upper half to a freshly allocated
  sibling, bump FNV/RNV and write back whole nodes;
* separator insertion into parents may cascade; unfinished cascades are safe
  to defer thanks to the B-link sibling property (Lehman&Yao) and are
  returned as a *repair queue* that the driver completes in later phases —
  the SIMD analogue of the classic half-split state.

All functions are shape-static and jit/shard_map friendly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hocl
from repro.core.ops import traverse
from repro.core.tree import (EMPTY_KEY, KEY_MIN, NULL_PTR, TreeConfig,
                             TreeState)

INT_MAX = jnp.int32(2**31 - 1)


# --------------------------------------------------------------------------
# small masked-scatter helpers (duplicate writes on the park row all carry
# identical values, so the scatter stays deterministic)
# --------------------------------------------------------------------------

def _park(cfg: TreeConfig, idx: jax.Array, do: jax.Array) -> jax.Array:
    return jnp.where(do, idx, jnp.int32(cfg.park_row))


def _scatter_entry(cfg, arr, row, col, val, do):
    """arr[row, col] = val where do; parked lanes rewrite the park value."""
    r = _park(cfg, row, do)
    c = jnp.where(do, col, 0)
    old = arr[r, c]
    return arr.at[r, c].set(jnp.where(do, val, old).astype(arr.dtype))


def _scatter_row1(cfg, arr, row, val, do):
    """arr[row] = val (per-node scalar field)."""
    r = _park(cfg, row, do)
    old = arr[r]
    return arr.at[r].set(jnp.where(do, val, old).astype(arr.dtype))


def _scatter_rowF(cfg, arr, row, val, do):
    """arr[row, :] = val[lane, :] (whole-node row write)."""
    r = _park(cfg, row, do)
    old = arr[r]
    return arr.at[r].set(jnp.where(do[:, None], val, old).astype(arr.dtype))


def _bump_entry_version(cfg, st: TreeState, row, col, do) -> TreeState:
    fev = _scatter_entry(cfg, st.fev, row, col,
                         (st.fev[_park(cfg, row, do),
                                 jnp.where(do, col, 0)] + 1) % 16, do)
    rev = _scatter_entry(cfg, st.rev, row, col,
                         (st.rev[_park(cfg, row, do),
                                 jnp.where(do, col, 0)] + 1) % 16, do)
    return st._replace(fev=fev, rev=rev)


def _bump_node_version(cfg, st: TreeState, row, do) -> TreeState:
    r = _park(cfg, row, do)
    fnv = st.fnv.at[r].set(jnp.where(do, (st.fnv[r] + 1) % 16, st.fnv[r]))
    rnv = st.rnv.at[r].set(jnp.where(do, (st.rnv[r] + 1) % 16, st.rnv[r]))
    return st._replace(fnv=fnv, rnv=rnv)


def _rank_by(node_key: jax.Array, active: jax.Array, sentinel_base: int):
    """FIFO rank of each active lane within its (node_key) group."""
    b = node_key.shape[0]
    lane = jnp.arange(b, dtype=jnp.int32)
    parked = jnp.where(active, node_key, sentinel_base + lane)
    perm = jnp.lexsort((lane, parked))
    inv = jnp.argsort(perm)
    s = parked[perm]
    newg = s != jnp.concatenate([jnp.full((1,), -7, s.dtype), s[:-1]])
    gid = jnp.cumsum(newg.astype(jnp.int32)) - 1
    start = jax.ops.segment_min(lane, gid, num_segments=b)
    rank_sorted = lane - start[gid]
    return rank_sorted[inv], newg[inv]


# --------------------------------------------------------------------------
# phase statistics
# --------------------------------------------------------------------------

class WriteStats(NamedTuple):
    """Structural counters for one write phase (netsim inputs).

    Per-lane arrays have batch shape [B]; scalars are 0-d.
    """
    applied_update: jax.Array     # [B] entry-granular update/insert applied
    applied_delete: jax.Array     # [B]
    applied_insert: jax.Array     # [B]
    miss_delete: jax.Array        # [B] delete of absent key (no write)
    superseded: jax.Array         # [B] op overwritten by later lane, no-op
    deferred: jax.Array           # [B] must retry next phase
    leaf: jax.Array               # [B] target leaf (cache accounting)
    hops: jax.Array               # [B] traversal descents
    local_size: jax.Array         # [B] HOCL local group size
    local_rank: jax.Array         # [B] FIFO rank inside the local group
    node_size: jax.Array          # [B] per-leaf conflict group size
    node_rank: jax.Array          # [B] FIFO rank among all ops on the leaf
    cs_rank: jax.Array            # [B] serialization rank of own CS group
    lock_cycles: jax.Array        # [B] remote lock cycles of own group
    local_head: jax.Array         # [B] head of local group
    cycle_head: jax.Array         # [B] lane issues the remote LOCK CAS
                                  #    under HOCL (verb plane)
    chain_end: jax.Array          # [B] lane issues the remote UNLOCK
                                  #    under HOCL (verb plane)
    split_mask: jax.Array         # [B] lane performed a leaf split (netsim
                                  #    split-lane pricing; with the split
                                  #    counts below, the cache-invalidation
                                  #    hook input)
    split_same_ms: jax.Array      # [B] lane's sibling landed on the same MS
                                  #    (three-way command combination §4.5)
    split_new_row: jax.Array      # [B] sibling row of the lane's split
                                  #    (park_row when no split) — verb
                                  #    plane targets the SIBLING write
    n_leaf_splits: jax.Array      # []
    n_internal_splits: jax.Array  # []
    n_root_splits: jax.Array      # []
    n_split_same_ms: jax.Array    # [] sibling allocated on same MS => 3-way
                                  #    command combination (paper §4.5)
    hocl_remote_cas: jax.Array    # []
    flat_remote_cas: jax.Array    # [] no-hierarchy baseline CAS count
    handovers: jax.Array          # []
    repair_backlog: jax.Array     # [] separators left in the repair queue


class RepairQueue(NamedTuple):
    """Deferred separator insertions (B-link half-splits to complete)."""
    sep: jax.Array       # [Q] separator key
    child: jax.Array     # [Q] right node to link
    level: jax.Array     # [Q] level of the split node (parent is level+1)
    valid: jax.Array     # [Q] bool

    @staticmethod
    def empty(q: int) -> "RepairQueue":
        return RepairQueue(
            sep=jnp.full((q,), EMPTY_KEY, jnp.int32),
            child=jnp.full((q,), NULL_PTR, jnp.int32),
            level=jnp.zeros((q,), jnp.int32),
            valid=jnp.zeros((q,), bool))


def _enqueue_pending(pend: RepairQueue, sep: jax.Array, child: jax.Array,
                     level: jax.Array, did: jax.Array) -> RepairQueue:
    """Insert the ``did`` lanes' separators into the queue's free slots.

    The r-th new entry (by lane order) lands in the r-th free slot;
    entries beyond the free capacity are dropped, which is safe under the
    B-link invariant — the half-split is rediscovered by a later
    traversal.  Shared by the write phase's split rounds and the repair
    cascade.
    """
    q = pend.sep.shape[0]
    free = ~pend.valid
    new_rank, _ = _rank_by(jnp.zeros_like(sep), did, 1)
    cumfree = jnp.cumsum(free.astype(jnp.int32)) - free.astype(jnp.int32)
    # index of the r-th free slot: first slot with cumfree == r
    slot_of_rank = jax.ops.segment_min(
        jnp.arange(q, dtype=jnp.int32),
        jnp.where(free, cumfree, q), num_segments=q + 1)[:q]
    can = did & (new_rank < jnp.sum(free.astype(jnp.int32)))
    tgt = jnp.where(can, slot_of_rank[jnp.minimum(new_rank, q - 1)], q)
    pad = lambda a, v: jnp.concatenate([a, jnp.array([v], a.dtype)])
    return RepairQueue(
        sep=pad(pend.sep, 0).at[tgt].set(jnp.where(can, sep, 0),
                                         mode="drop")[:q],
        child=pad(pend.child, 0).at[tgt].set(jnp.where(can, child, 0),
                                             mode="drop")[:q],
        level=pad(pend.level, 0).at[tgt].set(jnp.where(can, level, 0),
                                             mode="drop")[:q],
        valid=pad(pend.valid, False).at[tgt].set(can, mode="drop")[:q])


# --------------------------------------------------------------------------
# entry-granular application (the common, split-free path)
# --------------------------------------------------------------------------

def _apply_updates_deletes(cfg, st, leaf, slot, vals, upd, dele):
    do = upd | dele
    st = st._replace(
        vals=_scatter_entry(cfg, st.vals, leaf, slot, vals, upd),
        keys=_scatter_entry(cfg, st.keys, leaf, slot,
                            jnp.int32(EMPTY_KEY), dele))
    return _bump_entry_version(cfg, st, leaf, slot, do)


def _apply_inserts(cfg, st, leaf, keys, vals, ins):
    """Assign each new key a free slot of its leaf; overflows are returned."""
    rank, _ = _rank_by(leaf, ins, cfg.n_nodes)
    lk = st.keys[leaf]                               # post-update snapshot
    free = lk == EMPTY_KEY
    nfree = jnp.sum(free.astype(jnp.int32), axis=1)
    fits = ins & (rank < nfree)
    cum = jnp.cumsum(free.astype(jnp.int32), axis=1)
    hit = free & (cum == (rank + 1)[:, None])
    slot = jnp.argmax(hit, axis=1).astype(jnp.int32)
    st = st._replace(
        keys=_scatter_entry(cfg, st.keys, leaf, slot, keys, fits),
        vals=_scatter_entry(cfg, st.vals, leaf, slot, vals, fits))
    st = _bump_entry_version(cfg, st, leaf, slot, fits)
    return st, fits, ins & ~fits


# --------------------------------------------------------------------------
# node split (generic over leaf / internal nodes)
# --------------------------------------------------------------------------

def _split_nodes(cfg, st: TreeState, node: jax.Array, rep: jax.Array):
    """Split ``node`` for every lane where ``rep`` (one lane per node).

    Returns (state, sep, new_row, did_split, same_ms).  The split sets the
    sibling pointer atomically with the content move, so the tree is a valid
    B-link structure even before the parent learns about ``new_row``.
    """
    b = node.shape[0]
    f = cfg.fanout
    nk = st.keys[node]
    nv = st.vals[node]
    occupied = nk != EMPTY_KEY
    cnt = jnp.sum(occupied.astype(jnp.int32), axis=1)
    # a rep only splits a genuinely full-ish node (>= 2 entries)
    do = rep & (cnt >= 2)

    skey = jnp.where(occupied, nk, INT_MAX)
    order = jnp.argsort(skey, axis=1)
    sk = jnp.take_along_axis(nk, order, axis=1)      # sorted, EMPTY last
    sv = jnp.take_along_axis(nv, order, axis=1)
    keep = (cnt + 1) // 2                            # left keeps ceil half
    sep = jnp.take_along_axis(sk, keep[:, None], axis=1)[:, 0]

    # ---- allocate sibling rows (two-stage allocator, paper §4.2.4) ----
    rep_rank = jnp.cumsum(do.astype(jnp.int32)) - 1
    ms = ((st.alloc_rr + rep_rank) % cfg.n_ms).astype(jnp.int32)
    off, _ = _rank_by(ms, do, cfg.n_ms)
    new_local = st.alloc_next[ms] + off
    has_room = new_local < cfg.alloc_cap
    do = do & has_room
    new_row = jnp.where(do, ms * cfg.nodes_per_ms + new_local,
                        jnp.int32(cfg.park_row))
    n_alloc = jax.ops.segment_sum(do.astype(jnp.int32), ms,
                                  num_segments=cfg.n_ms)
    st = st._replace(alloc_next=st.alloc_next + n_alloc,
                     alloc_rr=st.alloc_rr + jnp.sum(do.astype(jnp.int32)))

    # ---- write the new (right) node ----
    idx = jnp.arange(f, dtype=jnp.int32)[None, :]
    right_src = jnp.minimum(keep[:, None] + idx, f - 1)
    in_right = (keep[:, None] + idx) < cnt[:, None]
    right_k = jnp.where(in_right, jnp.take_along_axis(sk, right_src, 1),
                        EMPTY_KEY)
    right_v = jnp.where(in_right, jnp.take_along_axis(sv, right_src, 1),
                        NULL_PTR)
    st = st._replace(
        keys=_scatter_rowF(cfg, st.keys, new_row, right_k, do),
        vals=_scatter_rowF(cfg, st.vals, new_row, right_v, do),
        fev=_scatter_rowF(cfg, st.fev, new_row, jnp.zeros((b, f)), do),
        rev=_scatter_rowF(cfg, st.rev, new_row, jnp.zeros((b, f)), do),
        fnv=_scatter_row1(cfg, st.fnv, new_row, jnp.zeros((b,)), do),
        rnv=_scatter_row1(cfg, st.rnv, new_row, jnp.zeros((b,)), do),
        level=_scatter_row1(cfg, st.level, new_row, st.level[node], do),
        fence_lo=_scatter_row1(cfg, st.fence_lo, new_row, sep, do),
        fence_hi=_scatter_row1(cfg, st.fence_hi, new_row,
                               st.fence_hi[node], do),
        sibling=_scatter_row1(cfg, st.sibling, new_row, st.sibling[node],
                              do),
        free_bit=_scatter_row1(cfg, st.free_bit, new_row,
                               jnp.zeros((b,), bool), do),
    )

    # ---- shrink the old (left) node; in-place, then bump FNV/RNV ----
    left_keep = occupied & (nk < sep[:, None])
    left_k = jnp.where(left_keep, nk, EMPTY_KEY)
    st = st._replace(
        keys=_scatter_rowF(cfg, st.keys, node, left_k, do),
        fence_hi=_scatter_row1(cfg, st.fence_hi, node, sep, do),
        sibling=_scatter_row1(cfg, st.sibling, node, new_row, do),
    )
    st = _bump_node_version(cfg, st, node, do)

    same_ms = do & (cfg.ms_of(new_row) == cfg.ms_of(node))
    return st, sep, new_row, do, same_ms


# --------------------------------------------------------------------------
# separator insertion into (sorted) internal nodes, with cascade
# --------------------------------------------------------------------------

def _internal_insert_once(cfg, st: TreeState, parent, sep, child, sel):
    """One sorted insert per distinct parent. Returns (st, ok, full)."""
    f = cfg.fanout
    nk = st.keys[parent]
    nv = st.vals[parent]
    valid = nk != EMPTY_KEY
    cnt = jnp.sum(valid.astype(jnp.int32), axis=1)
    dup = jnp.any(valid & (nk == sep[:, None]), axis=1)   # already repaired
    fits = sel & (cnt < f) & ~dup
    pos = jnp.sum((valid & (nk < sep[:, None])).astype(jnp.int32), axis=1)
    idx = jnp.arange(f, dtype=jnp.int32)[None, :]
    shift_src = jnp.maximum(idx - 1, 0)
    k_shift = jnp.take_along_axis(nk, shift_src, 1)
    v_shift = jnp.take_along_axis(nv, shift_src, 1)
    newk = jnp.where(idx == pos[:, None], sep[:, None],
                     jnp.where(idx > pos[:, None], k_shift, nk))
    newv = jnp.where(idx == pos[:, None], child[:, None],
                     jnp.where(idx > pos[:, None], v_shift, nv))
    st = st._replace(
        keys=_scatter_rowF(cfg, st.keys, parent, newk, fits),
        vals=_scatter_rowF(cfg, st.vals, parent, newv, fits),
    )
    st = _bump_node_version(cfg, st, parent, fits)
    return st, fits | (sel & dup), sel & (cnt >= f) & ~dup


def _root_split(cfg, st: TreeState, pend: RepairQueue):
    """Create a new root for (at most one) pending separator whose split
    node *was* the root."""
    lvl_arr = pend.level + 1
    tr = traverse(cfg, st, jnp.maximum(pend.sep, KEY_MIN),
                  stop_level_arr=lvl_arr)
    no_parent = pend.valid & (st.level[tr.leaf].astype(jnp.int32)
                              != lvl_arr)
    any_rs = jnp.any(no_parent)
    pick = jnp.argmax(no_parent)                      # lowest lane wins
    b = pend.sep.shape[0]
    is_pick = (jnp.arange(b) == pick) & no_parent

    # allocate the new root on the round-robin MS
    ms = (st.alloc_rr % cfg.n_ms).astype(jnp.int32)
    room = st.alloc_next[ms] < cfg.alloc_cap
    do_lane = is_pick & room
    do = jnp.any(do_lane)
    new_root = jnp.where(do, ms * cfg.nodes_per_ms + st.alloc_next[ms],
                         jnp.int32(cfg.park_row))
    f = cfg.fanout
    rk = jnp.full((b, f), EMPTY_KEY, jnp.int32)
    rk = rk.at[:, 0].set(KEY_MIN)
    rk = rk.at[:, 1].set(pend.sep)
    rv = jnp.full((b, f), NULL_PTR, jnp.int32)
    rv = rv.at[:, 0].set(st.root)
    rv = rv.at[:, 1].set(pend.child)
    row = jnp.where(do_lane, new_root, jnp.int32(cfg.park_row))
    st = st._replace(
        keys=_scatter_rowF(cfg, st.keys, row, rk, do_lane),
        vals=_scatter_rowF(cfg, st.vals, row, rv, do_lane),
        level=_scatter_row1(cfg, st.level, row, pend.level + 1, do_lane),
        fence_lo=_scatter_row1(cfg, st.fence_lo, row,
                               jnp.full((b,), KEY_MIN, jnp.int32), do_lane),
        fence_hi=_scatter_row1(cfg, st.fence_hi, row,
                               jnp.full((b,), INT_MAX, jnp.int32), do_lane),
    )
    st = st._replace(
        alloc_next=st.alloc_next.at[ms].add(jnp.where(do, 1, 0)),
        alloc_rr=st.alloc_rr + jnp.where(do, 1, 0),
        root=jnp.where(do, new_root, st.root),
        height=jnp.where(do, st.height + 1, st.height),
    )
    served = do_lane
    return st, pend._replace(valid=pend.valid & ~served), jnp.where(do, 1, 0)


def run_repair(cfg, st: TreeState, pend: RepairQueue, iters: int = 2):
    """Complete half-splits: push pending separators into parents.

    Each iteration handles ≤1 root split and ≤1 separator per parent, may
    split full parents (emitting new pending entries at the next level), and
    leaves the remainder in the queue — safe under B-link semantics.
    """
    n_internal = jnp.int32(0)
    n_root = jnp.int32(0)
    for _ in range(iters):
        st, pend, rs = _root_split(cfg, st, pend)
        n_root = n_root + rs
        tr = traverse(cfg, st, jnp.maximum(pend.sep, KEY_MIN),
                      stop_level_arr=pend.level + 1)
        parent = tr.leaf
        ok_level = st.level[parent].astype(jnp.int32) == pend.level + 1
        rank, _ = _rank_by(parent, pend.valid & ok_level, cfg.n_nodes)
        sel = pend.valid & ok_level & (rank == 0)
        st, done, full = _internal_insert_once(cfg, st, parent, pend.sep,
                                               pend.child, sel)
        pend = pend._replace(valid=pend.valid & ~done)
        # split the full parents; their separators enter the queue in the
        # slots of lanes that just completed (compaction via free slots)
        st, psep, pchild, did, _ = _split_nodes(cfg, st, parent, full)
        n_internal = n_internal + jnp.sum(did.astype(jnp.int32))
        pend = _enqueue_pending(pend, psep, pchild,
                                st.level[parent].astype(jnp.int32), did)
    return st, pend, n_internal, n_root


# --------------------------------------------------------------------------
# the full write phase
# --------------------------------------------------------------------------

def write_phase(cfg: TreeConfig, st: TreeState, keys, vals, is_delete,
                active, cs, repair: RepairQueue | None = None,
                split_rounds: int = 2, repair_iters: int = 2):
    """Apply one batch of write ops. Returns (state, done, stats, repair).

    ``done[i]`` False means lane i must be resubmitted (leaf still
    overflowing after ``split_rounds``, or allocator backpressure) — the
    batched analogue of a client retry.
    """
    b = keys.shape[0]
    lane = jnp.arange(b, dtype=jnp.int32)
    if repair is None:
        repair = RepairQueue.empty(b)

    # -- intra-batch dedupe: last op per key wins (DESIGN.md §8) --
    parked_key = jnp.where(active, keys, -10 - lane)
    perm = jnp.lexsort((lane, parked_key))
    inv = jnp.argsort(perm)
    ks = parked_key[perm]
    nxt = jnp.concatenate([ks[1:], jnp.full((1,), -7, ks.dtype)])
    last_of_key = (ks != nxt)[inv]
    act = active & last_of_key
    superseded = active & ~last_of_key

    # -- route + conflict groups (lock plane) --
    # NOTE: groups are computed over ALL active lanes (pre-dedupe): every
    # client op contends for the leaf lock in the real system even when a
    # later op overwrites its value — dedupe is an application-plane
    # equivalence, not a contention reducer.
    tr = traverse(cfg, st, keys)
    groups = hocl.group_by_node(cfg, tr.leaf, cs, active)
    lock_stats = hocl.lock_phase_stats(cfg, groups, active)

    # -- classify against the leaf image --
    lk = st.keys[tr.leaf]
    eq = lk == keys[:, None]
    found = jnp.any(eq, axis=1)
    slot = jnp.argmax(eq, axis=1).astype(jnp.int32)
    upd = act & found & ~is_delete
    dele = act & found & is_delete
    miss_del = act & ~found & is_delete
    ins = act & ~found & ~is_delete

    st = _apply_updates_deletes(cfg, st, tr.leaf, slot, vals, upd, dele)
    st, ins_done, ins_defer = _apply_inserts(cfg, st, tr.leaf, keys, vals,
                                             ins)

    n_leaf_splits = jnp.int32(0)
    n_same_ms = jnp.int32(0)
    n_internal = jnp.int32(0)
    n_root = jnp.int32(0)
    split_mask = jnp.zeros((b,), bool)
    split_same = jnp.zeros((b,), bool)
    split_row = jnp.full((b,), jnp.int32(cfg.park_row))

    # -- split rounds for overflowing leaves --
    for _ in range(split_rounds):
        tr2 = traverse(cfg, st, keys)
        rank0, head = _rank_by(tr2.leaf, ins_defer, cfg.n_nodes)
        rep = ins_defer & (rank0 == 0)
        st, sep, new_row, did, same = _split_nodes(cfg, st, tr2.leaf, rep)
        n_leaf_splits += jnp.sum(did.astype(jnp.int32))
        n_same_ms += jnp.sum(same.astype(jnp.int32))
        split_mask = split_mask | did
        split_same = split_same | same
        split_row = jnp.where(did, new_row, split_row)
        # enqueue separators in the repair queue (free slots)
        repair = _enqueue_pending(repair, sep, new_row,
                                  st.level[new_row].astype(jnp.int32), did)
        st, repair, ni, nr = run_repair(cfg, st, repair, iters=repair_iters)
        n_internal += ni
        n_root += nr
        # retry the deferred inserts after the splits
        tr3 = traverse(cfg, st, keys)
        st, done2, ins_defer = _apply_inserts(cfg, st, tr3.leaf, keys, vals,
                                              ins_defer)
        ins_done = ins_done | done2

    done = (upd | dele | miss_del | ins_done | superseded | ~active)
    stats = WriteStats(
        applied_update=upd, applied_delete=dele,
        applied_insert=ins_done, miss_delete=miss_del,
        superseded=superseded, deferred=active & ~done,
        leaf=tr.leaf, hops=tr.hops,
        local_size=groups.local_size, local_rank=groups.local_rank,
        node_size=groups.node_size, node_rank=groups.node_rank,
        cs_rank=groups.cs_rank, lock_cycles=groups.lock_cycles,
        local_head=groups.local_head,
        cycle_head=groups.cycle_head, chain_end=groups.chain_end,
        split_mask=split_mask,
        split_same_ms=split_same, split_new_row=split_row,
        n_leaf_splits=n_leaf_splits, n_internal_splits=n_internal,
        n_root_splits=n_root, n_split_same_ms=n_same_ms,
        hocl_remote_cas=lock_stats["hocl_remote_cas"],
        flat_remote_cas=lock_stats["flat_remote_cas"],
        handovers=lock_stats["handovers"],
        repair_backlog=jnp.sum(repair.valid.astype(jnp.int32)),
    )
    return st, done, stats, repair
