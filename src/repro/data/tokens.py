"""Synthetic LM data pipeline: deterministic, shardable, restartable.

Generates Zipf-ish token streams (real corpora are Zipfian — same reason the
paper's YCSB keys are) packed into fixed [B, S] batches.  ``skip`` supports
exact resume after checkpoint restore.  Stub embeddings for the audio/vlm
frontends are generated alongside.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.models.common import ArchConfig


def zipf_tokens(rng: np.random.Generator, n: int, vocab: int,
                alpha: float = 1.1) -> np.ndarray:
    # inverse-CDF Zipf over the vocab, cheap and deterministic
    u = rng.random(n)
    ranks = np.exp(u * np.log(vocab)) - 1.0
    return np.minimum(ranks.astype(np.int64), vocab - 1).astype(np.int32)


def synthetic_batches(cfg: ArchConfig, batch: int, seq: int,
                      seed: int = 0, skip: int = 0) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    i = 0
    while True:
        toks = zipf_tokens(rng, batch * seq, cfg.vocab).reshape(batch, seq)
        out = {"tokens": toks}
        if cfg.family == "audio":
            out["frames"] = rng.standard_normal(
                (batch, cfg.n_frames, cfg.d_model)).astype(np.float32)
        if cfg.family == "vlm":
            out["patches"] = rng.standard_normal(
                (batch, cfg.n_patches, cfg.d_model)).astype(np.float32)
        if i >= skip:
            yield out
        i += 1
