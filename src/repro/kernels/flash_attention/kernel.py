"""Pallas TPU flash attention (causal, GQA), online-softmax streaming.

Grid: (batch, q_heads, q_blocks, kv_blocks) — the kv axis is innermost, so
each (b, h, iq) revisits its accumulator scratch across kv steps (TPU grids
execute sequentially over the trailing axis).  Blocks are VMEM-resident:

  q:   [1, 1, BQ, hd]   index (b, h, iq, 0)
  k/v: [1, 1, BK, hd]   index (b, h // group, ik, 0)   (GQA: shared KV head)
  o:   [1, 1, BQ, hd]   written at the last kv step

Scratch: acc [BQ, hd] f32, m/l [BQ, 128] f32 (lane-padded running max/sum).
Causal blocks strictly above the diagonal are masked via pl.when; MXU dims
(BQ, BK, hd) should be multiples of 128 for full utilization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
                  bq: int, bk: int, causal: bool, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    run = True
    if causal:
        # skip blocks strictly above the causal diagonal
        run = (ik * bk) <= (iq * bq + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # [BQ, hd]
        k = k_ref[0, 0].astype(jnp.float32)               # [BK, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 0)
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_s[:, 0]                                 # [BQ]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = alpha * l_s[:, 0] + jnp.sum(p, axis=1)
        acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = jnp.broadcast_to(m_cur[:, None], m_s.shape)
        l_s[...] = jnp.broadcast_to(l_cur[:, None], l_s.shape)

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_s[:, 0], 1e-30)[:, None]
        o_ref[0, 0] = (acc[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: [B, H, Sq, hd]; k, v: [B, KV, Sk, hd] -> [B, H, Sq, hd]."""
    b, h, sq, hd = q.shape
    _, kvh, sk, _ = k.shape
    assert h % kvh == 0, "q heads must be a multiple of kv heads"
    group = h // kvh
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0
    grid = (b, h, sq // bq, sk // bk)
    scale = hd ** -0.5

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b_, h_, iq, ik:
                         (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b_, h_, iq, ik:
                         (b_, h_ // group, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b_, h_, iq, ik:
                         (b_, h_ // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b_, h_, iq, ik:
                               (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
