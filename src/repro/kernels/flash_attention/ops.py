"""jit'd public wrapper: [B,S,H,hd] model layout <-> kernel layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *,
               causal: bool = True, interpret: bool = False) -> jax.Array:
    """q: [B,S,H,hd]; k,v: [B,S,KV,hd] (model layout) -> [B,S,H,hd]."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = flash_attention(qt, kt, vt, causal=causal, interpret=interpret)
    return jnp.swapaxes(o, 1, 2)
