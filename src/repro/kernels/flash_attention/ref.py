"""Pure-jnp oracle for flash attention (same [B,H,S,hd] layout)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    b, h, sq, hd = q.shape
    _, kvh, sk, _ = k.shape
    group = h // kvh
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * hd ** -0.5
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vx.astype(jnp.float32)).astype(q.dtype)
