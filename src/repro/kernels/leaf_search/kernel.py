"""Pallas TPU kernel for Sherman's hot read path: batched unsorted-leaf
search with the two-level version check (paper Fig. 9).

The paper's unsorted leaves force a full-node scan per lookup; on the memory
server this is the NIC's job, on TPU it is a VPU sweep over the leaf image
held in VMEM.  A batch of fetched leaf images is tiled [BT, F] so each grid
step compares BT query keys against all F slots simultaneously — the SIMD
analogue of Sherman's "traverse the entire targeted leaf node", with the
version words (FEV/REV/FNV/RNV — the on-chip-memory resident metadata)
validated in the same pass.

Inputs are the *gathered* leaf rows (HBM -> VMEM by BlockSpec); outputs are
value / found / consistent per lane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _leaf_kernel(qk_ref, keys_ref, vals_ref, fev_ref, rev_ref,
                 fnv_ref, rnv_ref, free_ref,
                 val_ref, found_ref, cons_ref, *, empty_key: int):
    qk = qk_ref[...]                         # [BT]
    keys = keys_ref[...]                     # [BT, F]
    vals = vals_ref[...]
    eq = keys == qk[:, None]
    found = jnp.any(eq, axis=1)
    # first-match one-hot select (unsorted full scan; keys unique per leaf,
    # first-match keeps the kernel deterministic regardless)
    first = eq & (jnp.cumsum(eq.astype(jnp.int32), axis=1) == 1)
    sel = lambda a: jnp.sum(jnp.where(first, a, 0), axis=1)
    value = sel(vals)
    fev = sel(fev_ref[...].astype(jnp.int32))
    rev = sel(rev_ref[...].astype(jnp.int32))
    node_ok = (fnv_ref[...] == rnv_ref[...]) & (free_ref[...] == 0)
    entry_ok = fev == rev
    consistent = node_ok & (entry_ok | ~found)
    val_ref[...] = jnp.where(found & consistent, value,
                             jnp.int32(-1))
    found_ref[...] = (found & consistent).astype(jnp.int32)
    cons_ref[...] = consistent.astype(jnp.int32)


def leaf_search(qkeys: jax.Array, keys: jax.Array, vals: jax.Array,
                fev: jax.Array, rev: jax.Array, fnv: jax.Array,
                rnv: jax.Array, free: jax.Array, *,
                bt: int = 256, empty_key: int = -1,
                interpret: bool = False):
    """qkeys [B]; keys/vals/fev/rev [B, F]; fnv/rnv/free [B].

    Returns (value [B], found [B] bool, consistent [B] bool).
    """
    b, f = keys.shape
    bt = min(bt, b)
    assert b % bt == 0
    grid = (b // bt,)
    row = lambda i: (i, 0)
    vec = lambda i: (i,)
    kernel = functools.partial(_leaf_kernel, empty_key=empty_key)
    value, found, cons = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt,), vec),
            pl.BlockSpec((bt, f), row),
            pl.BlockSpec((bt, f), row),
            pl.BlockSpec((bt, f), row),
            pl.BlockSpec((bt, f), row),
            pl.BlockSpec((bt,), vec),
            pl.BlockSpec((bt,), vec),
            pl.BlockSpec((bt,), vec),
        ],
        out_specs=[pl.BlockSpec((bt,), vec)] * 3,
        out_shape=[jax.ShapeDtypeStruct((b,), jnp.int32)] * 3,
        interpret=interpret,
    )(qkeys, keys, vals, fev, rev, fnv, rnv, free)
    return value, found.astype(bool), cons.astype(bool)
