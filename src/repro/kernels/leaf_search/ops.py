"""jit'd wrapper: gather leaf rows from the pool and search them.

On TPU the gather stages HBM rows into VMEM via the BlockSpec pipeline; on
CPU tests the kernel runs under interpret=True against the ref oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.tree import TreeConfig, TreeState
from repro.kernels.leaf_search.kernel import leaf_search


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def lookup_leaves(cfg: TreeConfig, st: TreeState, leaf: jax.Array,
                  qkeys: jax.Array, interpret: bool = True):
    """Kernel-backed equivalent of core.ops.leaf_lookup."""
    return leaf_search(
        qkeys,
        st.keys[leaf], st.vals[leaf],
        st.fev[leaf], st.rev[leaf],
        st.fnv[leaf].astype(jnp.int32), st.rnv[leaf].astype(jnp.int32),
        st.free_bit[leaf].astype(jnp.int32),
        bt=min(256, qkeys.shape[0]), interpret=interpret)
