"""Pure-jnp oracle for the leaf-search kernel (mirrors core.ops)."""
from __future__ import annotations

import jax.numpy as jnp


def leaf_search_ref(qkeys, keys, vals, fev, rev, fnv, rnv, free):
    eq = keys == qkeys[:, None]
    found = jnp.any(eq, axis=1)
    slot = jnp.argmax(eq, axis=1)
    take = lambda a: jnp.take_along_axis(a, slot[:, None], axis=1)[:, 0]
    node_ok = (fnv == rnv) & (free == 0)
    entry_ok = take(fev.astype(jnp.int32)) == take(rev.astype(jnp.int32))
    consistent = node_ok & (entry_ok | ~found)
    value = jnp.where(found & consistent, take(vals), jnp.int32(-1))
    return value, found & consistent, consistent
