"""Pallas TPU kernel for the WKV6 recurrence (RWKV6 time mixing).

Per (batch, head): S_t = diag(w_t) S_{t-1} + k_t^T v_t,
                   o_t = r_t (S_{t-1} + diag(u) k_t^T v_t).

Grid: (B, H, T/BT) — the time axis is innermost so the [N, N] state scratch
carries across time blocks in VMEM (the same revisiting pattern as the flash
kernel).  Each grid step streams a [BT, N] block of r/k/v/w through the VPU
and steps the recurrence BT times with a fori_loop; N = 64 keeps the state
(64×64×4 B = 16 KB) comfortably VMEM-resident — this is the TPU analogue of
keeping the hot lock/state table on-chip (paper §4.3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr, *,
                bt: int, n: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[0, 0].astype(jnp.float32)            # [N]

    def step(t, s):
        r = r_ref[0, 0, t].astype(jnp.float32)     # [N]
        k = k_ref[0, 0, t].astype(jnp.float32)
        v = v_ref[0, 0, t].astype(jnp.float32)
        w = w_ref[0, 0, t].astype(jnp.float32)
        kv = k[:, None] * v[None, :]               # [N, N]
        out = jnp.sum((s + u[:, None] * kv) * r[:, None], axis=0)
        o_ref[0, 0, t] = out.astype(o_ref.dtype)
        return w[:, None] * s + kv

    s_scr[...] = jax.lax.fori_loop(0, bt, step, s_scr[...])


def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, *, bt: int = 128, interpret: bool = False
         ) -> jax.Array:
    """r/k/v/w: [B, H, T, N]; u: [H, N] -> o [B, H, T, N]."""
    b, h, t, n = r.shape
    bt = min(bt, t)
    assert t % bt == 0
    grid = (b, h, t // bt)
    blk = pl.BlockSpec((1, 1, bt, n), lambda b_, h_, it: (b_, h_, it, 0))
    ublk = pl.BlockSpec((1, 1, n), lambda b_, h_, it: (0, h_, 0))
    kernel = functools.partial(_wkv_kernel, bt=bt, n=n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[blk, blk, blk, blk, ublk],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((b, h, t, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u[None])
