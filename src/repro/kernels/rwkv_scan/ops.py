"""jit'd wrapper for the WKV6 kernel (drop-in for the model's time scan)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rwkv_scan.kernel import wkv6


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv6_apply(r, k, v, w, u, interpret: bool = True):
    return wkv6(r, k, v, w, u, interpret=interpret)
