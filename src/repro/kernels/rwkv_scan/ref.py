"""Pure-jnp oracle for the WKV6 recurrence (lax.scan over time)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u):
    """r/k/v/w: [B, H, T, N]; u: [H, N] -> o [B, H, T, N] (fp32)."""
    b, h, t, n = r.shape
    r32, k32, v32, w32 = (x.astype(jnp.float32) for x in (r, k, v, w))
    u32 = u.astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp                       # [B, H, N]
        kv = kt[..., :, None] * vt[..., None, :]   # [B, H, N, N]
        out = jnp.sum((s + u32[None, :, :, None] * kv)
                      * rt[..., :, None], axis=-2)
        return wt[..., :, None] * s + kv, out

    xs = tuple(jnp.moveaxis(x, 2, 0) for x in (r32, k32, v32, w32))
    s0 = jnp.zeros((b, h, n, n), jnp.float32)
    _, out = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(out, 0, 2)
