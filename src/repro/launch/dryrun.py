import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST precede any other import (jax locks the device
count on first init): the dry-run — and only the dry-run — sees 512
placeholder CPU devices so the production meshes can be built.

Per cell the driver does TWO things:

1. **Real compile** (scan-over-layers, the production program): proves the
   sharding lowers + compiles on the target mesh and records
   ``memory_analysis()`` for the true layer count.

2. **Cost probes**: XLA's ``cost_analysis`` counts while-loop bodies ONCE,
   so a scanned 95-layer model would report ~1/95th of its FLOPs.  We
   therefore lower small *unrolled* probes (1 and 2 layer-units) and
   extrapolate linearly — exact for homogeneous stacks: cost(L) = a + b*L.
   RWKV's time-axis while loop gets one extra probe at S/2 (see
   ``_rwkv_corrected``).  Collective bytes follow the same algebra.

Programs per shape: train_4k -> sharded train_step (fwd+bwd+AdamW);
prefill_32k -> api.prefill; decode_* -> api.decode_step (1 token vs
seq-len state).  Results go to one JSON per cell (incremental cache).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out experiments/dryrun [--attn chunked] [--force]
"""
import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P   # noqa: E402

from repro import configs as cfglib                    # noqa: E402
from repro.launch import shapes as shp                 # noqa: E402
from repro.launch.mesh import make_production_mesh     # noqa: E402
from repro.launch.train import make_train_step         # noqa: E402
from repro.models.registry import build                # noqa: E402
from repro.optim import adamw                          # noqa: E402
from repro.parallel import sharding as sh              # noqa: E402
from repro.roofline import analyze                     # noqa: E402


def _ns(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def count_params(params_spec, cfg) -> tuple[float, float]:
    """(total, active) parameter counts from the spec tree."""
    total = sum(float(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(params_spec))
    active = total
    if cfg.is_moe:
        names = jax.tree_util.tree_leaves(sh.name_tree(params_spec))
        leaves = jax.tree_util.tree_leaves(params_spec)
        expert = sum(float(np.prod(l.shape))
                     for n, l in zip(names, leaves)
                     if ".moe.w_" in n)
        active = total - expert * (1 - cfg.top_k / cfg.n_experts)
    return total, active


def probe_cfg(cfg, k: int):
    """Config with k layer-units, unrolled (see module docstring).

    Returns (cfg_k, units_real): linear extrapolation target is
    cost(units_real) from probes at units k=1,2.
    """
    if cfg.family == "hybrid":
        # unit = (rec, rec, attn) super-block; tail rec layers ≈ 1/3 super
        from repro.models import rglru
        units_real = rglru.n_super(cfg) + rglru.n_tail(cfg) / 3.0
        return dataclasses.replace(cfg, n_layers=3 * k,
                                   unroll_layers=True), units_real
    if cfg.family == "audio":
        # unit = one encoder + one decoder layer (24/24 in whisper-medium)
        units_real = cfg.n_layers
        return dataclasses.replace(cfg, n_layers=k, n_enc_layers=k,
                                   unroll_layers=True), units_real
    return dataclasses.replace(cfg, n_layers=k,
                               unroll_layers=True), cfg.n_layers


def _lower_program(cfg, shape, multi_pod, opt_cfg):
    """Build + lower the cell's program for a given config variant."""
    api = build(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    params_spec = shp.params_specs(api)
    p_pspec = sh.params_pspecs(params_spec, mesh)

    if shape.kind == "train":
        batch_spec = shp.batch_specs(cfg, shape)
        opt_spec = jax.eval_shape(adamw.init, params_spec)
        o_pspec = adamw.AdamWState(step=P(), m=p_pspec, v=p_pspec)
        b_pspec = sh.batch_pspecs(batch_spec, mesh)
        step = make_train_step(api, opt_cfg or adamw.AdamWConfig())
        jitted = jax.jit(
            step,
            in_shardings=(_ns(mesh, p_pspec), _ns(mesh, o_pspec),
                          _ns(mesh, b_pspec)),
            out_shardings=(_ns(mesh, p_pspec), _ns(mesh, o_pspec), None),
            donate_argnums=(0, 1))
        with mesh:
            return jitted.lower(params_spec, opt_spec, batch_spec), \
                params_spec, mesh
    if shape.kind == "prefill":
        batch_spec = shp.batch_specs(cfg, shape)
        b_pspec = sh.batch_pspecs(batch_spec, mesh)
        if api.prefill is not None:
            def prefill_fn(params, batch):
                return api.prefill(params, batch, shape.seq)
        else:                      # recurrent: prefill == full forward
            def prefill_fn(params, batch):
                return api.loss(params, batch)
        jitted = jax.jit(prefill_fn,
                         in_shardings=(_ns(mesh, p_pspec),
                                       _ns(mesh, b_pspec)))
        with mesh:
            return jitted.lower(params_spec, batch_spec), params_spec, mesh
    # decode
    state_spec = shp.decode_state_specs(api, params_spec, shape)
    s_pspec = sh.decode_state_pspecs(state_spec, mesh)
    tok_spec = shp.token_spec(shape)
    dsize = int(np.prod([mesh.shape[a] for a in sh.dp_axes(mesh)]))
    t_pspec = (P(sh.dp_axes(mesh)) if shape.batch % dsize == 0
               and shape.batch >= dsize else P())
    jitted = jax.jit(
        api.decode_step,
        in_shardings=(_ns(mesh, p_pspec), _ns(mesh, s_pspec),
                      NamedSharding(mesh, t_pspec)),
        out_shardings=(None, _ns(mesh, s_pspec)),
        donate_argnums=(1,))
    with mesh:
        return jitted.lower(params_spec, state_spec, tok_spec), \
            params_spec, mesh


def _probe_costs(cfg, shape, multi_pod, opt_cfg, attn):
    """Unrolled 1/2-unit probes -> exact per-unit HLO costs."""
    out = {}
    for k in (1, 2):
        cfg_k, units_real = probe_cfg(
            dataclasses.replace(cfg, attn_impl=attn), k)
        lowered, _, _ = _lower_program(cfg_k, shape, multi_pod, opt_cfg)
        compiled = lowered.compile()
        info = analyze.analyze_compiled(compiled)
        out[k] = info
    b = {m: out[2][m] - out[1][m]
         for m in ("flops", "bytes_accessed")}
    b["coll"] = out[2]["collectives"]["total"] \
        - out[1]["collectives"]["total"]
    a = {m: out[1][m] - b[m] for m in ("flops", "bytes_accessed")}
    a["coll"] = out[1]["collectives"]["total"] - b["coll"]

    def extrap(units):
        return {m: max(a[m] + b[m] * units, 0.0)
                for m in ("flops", "bytes_accessed", "coll")}

    _, units_real = probe_cfg(cfg, 1)
    est = extrap(units_real)
    est["units_real"] = units_real
    est["per_unit"] = b
    est["fixed"] = a
    return est


def _rwkv_time_corrected(cfg, shape, multi_pod, opt_cfg, attn, est):
    """RWKV train/prefill: the WKV recurrence is the only remaining while
    loop after the layer-major restructure, and its body is *structurally
    known* — a weight-free elementwise state update with NO collectives
    (state and streams are head-sharded; every op is shard-local).  We
    therefore add the analytic per-token body on top of the layer-probe
    extrapolation (which counted the loop body once — a <0.1% overlap):

      per token/layer:  flops ≈ 5·B·H·N²  (kv outer + out + decay-update,
                        fwd; ×3 for bwd recompute+grads)
      bytes ≈ state r/w (2·B·H·N²·4 B, ÷chunk when chunked) + rkvw slices
      collectives: 0  (so the probe-extrapolated value stands)
    """
    mesh_div = 16   # model-axis shards of the H dim
    b_dev = shape.batch // 16 if shape.batch >= 16 else shape.batch
    h = cfg.d_model // cfg.rwkv_head_dim
    h_dev = max(h // mesh_div, 1)
    n = cfg.rwkv_head_dim
    layers = cfg.n_layers
    mult = 3.0 if shape.kind == "train" else 1.0   # bwd recompute+grad
    body_flops = 5.0 * b_dev * h_dev * n * n * mult
    chunk = max(cfg.rwkv_chunk, 1)
    state_rw = 2.0 * b_dev * h_dev * n * n * 4.0 / chunk
    stream = 5.0 * b_dev * h_dev * n * 4.0
    body_bytes = (state_rw + stream) * mult
    s = shape.seq
    return dict(
        flops=est["flops"] + s * layers * body_flops,
        bytes_accessed=est["bytes_accessed"] + s * layers * body_bytes,
        coll=est["coll"],
    )


def pad_heads_cfg(cfg):
    """Deployment padding: q-heads up to a multiple of 16 (and kv heads up
    to a divisor of that) so attention shards over the model axis instead
    of being replicated.  head_dim is pinned so only the head count grows
    (a deployment superset of the assigned config — EXPERIMENTS §Perf)."""
    if cfg.n_heads == 0 or cfg.n_heads % 16 == 0:
        return cfg
    h = -(-cfg.n_heads // 16) * 16
    kv = max(cfg.n_kv_heads, 1)
    while h % kv:
        kv += 1
    return dataclasses.replace(cfg, n_heads=h, n_kv_heads=kv,
                               head_dim=cfg.hd)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               opt_cfg=None, attn: str = "naive",
               moe_pad: bool = False, rwkv_chunk: int = 0,
               pad_heads: bool = False) -> dict:
    cfg = cfglib.get(arch)
    cfg = dataclasses.replace(cfg, attn_impl=attn, moe_pad_experts=moe_pad,
                              rwkv_chunk=rwkv_chunk)
    if pad_heads:
        cfg = pad_heads_cfg(cfg)
    shape = shp.SHAPES[shape_name]
    ok, why = shp.cell_supported(cfg, shape)
    if not ok:
        return dict(status="skipped", reason=why)

    # ---- 1. real compile (scan form, true layer count) ----
    t0 = time.time()
    lowered, params_spec, mesh = _lower_program(cfg, shape, multi_pod,
                                                opt_cfg)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    print(compiled.memory_analysis())
    print({k: v for k, v in (compiled.cost_analysis() or {}).items()
           if k in ("flops", "bytes accessed")})
    real = analyze.analyze_compiled(compiled)

    # ---- 2. unrolled cost probes (exact per-layer accounting) ----
    est = _probe_costs(cfg, shape, multi_pod, opt_cfg, attn)
    if cfg.family == "ssm" and shape.kind in ("train", "prefill"):
        corrected = _rwkv_time_corrected(cfg, shape, multi_pod, opt_cfg,
                                         attn, est)
        est.update(corrected)
        est["time_loop_corrected"] = True

    flops = est["flops"]
    bytes_accessed = est["bytes_accessed"]
    coll = est["coll"]
    rl = analyze.roofline(flops, bytes_accessed, coll)

    n_chips = int(np.prod(list(mesh.shape.values())))
    n_total, n_active = count_params(params_spec, cfg)
    training = shape.kind == "train"
    tokens = shape.batch * (shape.seq if shape.kind != "decode" else 1)
    mf = analyze.model_flops(n_active, tokens, training)
    hlo_global = flops * n_chips
    return dict(
        status="ok", arch=arch, shape=shape_name,
        mesh="multi" if multi_pod else "single", n_chips=n_chips,
        params_total=n_total, params_active=n_active,
        tokens=tokens, model_flops=mf, attn=attn,
        flops=flops, bytes_accessed=bytes_accessed,
        collective_bytes=coll, roofline=rl,
        useful_ratio=mf / hlo_global if hlo_global else 0.0,
        scan_compile=dict(
            lower_s=t_lower, compile_s=t_compile,
            memory=real["memory"],
            raw_flops_scan_counted_once=real["flops"],
            collectives_per_kind=real["collectives"]["per_kind"],
            collective_counts=real["collectives"]["counts"]),
        probes=dict(per_unit=est["per_unit"], fixed=est["fixed"],
                    units_real=est["units_real"],
                    time_loop_corrected=est.get("time_loop_corrected",
                                                False)),
    )


ARCH_NAMES = [a.replace("_", "-") for a in cfglib.ALL_ARCHS]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--attn", default="naive", choices=["naive", "chunked"])
    ap.add_argument("--moe-pad", action="store_true")
    ap.add_argument("--rwkv-chunk", type=int, default=0)
    ap.add_argument("--pad-heads", action="store_true")
    args = ap.parse_args(argv)

    archs = ARCH_NAMES if args.arch == "all" else [args.arch]
    shapes = list(shp.SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                tag = f"{cfglib.canon(arch)}__{shape_name}__" \
                      f"{'multi' if multi else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[dryrun] cached {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    info = lower_cell(arch, shape_name, multi,
                                      attn=args.attn,
                                      moe_pad=args.moe_pad,
                                      rwkv_chunk=args.rwkv_chunk,
                                      pad_heads=args.pad_heads)
                except Exception as e:
                    info = dict(status="error", error=str(e),
                                traceback=traceback.format_exc())
                    failures += 1
                    print(f"[dryrun] FAILED {tag}: {e}")
                with open(path, "w") as f:
                    json.dump(info, f, indent=2, default=str)
                if info.get("status") == "ok":
                    rl = info["roofline"]
                    print(f"[dryrun] {tag}: dominant={rl['dominant']} "
                          f"bound={rl['bound_s'] * 1e3:.2f}ms "
                          f"compile={info['scan_compile']['compile_s']:.0f}s",
                          flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")
    print("[dryrun] all requested cells done")


if __name__ == "__main__":
    main()
