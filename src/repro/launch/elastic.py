"""Elastic scaling: re-mesh and re-shard live state after device-set
changes (node failure / scale-up).

On a real fleet the controller detects a missing host, reforms the mesh
from surviving devices, and every jitted step recompiles against the new
mesh; parameters/optimizer state are re-sharded with ``jax.device_put``
(resumable from the checkpoint manager if hosts were lost).  This module is
the mesh-math + resharding piece, exercised in tests with virtual devices.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.parallel import sharding as sh


def reform_mesh(devices: Sequence, data: int | None = None,
                model: int | None = None) -> Mesh:
    """Largest (data, model) mesh that fits the surviving devices.

    Keeps the model axis as large as possible (TP degree is tied to weight
    shard shapes), shrinking the data axis first — the standard elastic-DP
    policy."""
    n = len(devices)
    if model is None:
        model = n
        while model > 1 and n % model:
            model -= 1
    data = data or n // model
    if data * model > n:
        raise ValueError(f"{data}x{model} mesh needs {data * model} "
                         f"devices, have {n}")
    dev = np.asarray(devices[: data * model]).reshape(data, model)
    return Mesh(dev, ("data", "model"))


def reshard_params(params: Any, new_mesh: Mesh) -> Any:
    """Move a parameter pytree onto a new mesh (same logical specs)."""
    shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(new_mesh, s),
        sh.params_pspecs(params, new_mesh),
        is_leaf=lambda x: isinstance(x, PartitionSpec))
    return jax.device_put(params, shardings)


def drop_devices(mesh: Mesh, n_failed: int) -> Mesh:
    """Simulate losing ``n_failed`` devices: reform from the survivors."""
    flat = list(np.asarray(mesh.devices).reshape(-1))
    survivors = flat[:-n_failed] if n_failed else flat
    model = mesh.shape.get("model", 1)
    while model > 1 and len(survivors) % model:
        survivors = survivors[:-1]
    data = len(survivors) // model
    return reform_mesh(survivors, data=data, model=model)
