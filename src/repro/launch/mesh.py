"""Production mesh construction.

A pod is a (data=16, model=16) = 256-chip TPU v5e slice; the multi-pod mesh
adds a leading ``pod`` axis (2 pods = 512 chips for the dry-run; the axis
generalizes to N pods).  Defined as functions so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types`` exists only on newer jax; older versions default to
    Auto semantics, so omitting it is equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """A small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_type_kwargs(2))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per chip per direction)
