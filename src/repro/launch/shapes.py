"""Assigned input-shape cells and their ShapeDtypeStruct stand-ins.

Every LM-family arch pairs with four shapes; ``decode_*`` / ``long_*`` lower
``serve_step`` (one token against a seq_len KV cache / recurrent state), not
``train_step``.  ``long_500k`` requires sub-quadratic attention and is
skipped (with a reason) for pure full-attention archs — see DESIGN.md §6.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig
from repro.models.registry import ModelAPI


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def cell_supported(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("full O(L^2) attention at 524288 tokens — "
                       "sub-quadratic archs only (DESIGN.md §6)")
    return True, ""


def batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for the data batch (no allocation)."""
    out = {"tokens": jax.ShapeDtypeStruct((shape.batch, shape.seq),
                                          jnp.int32)}
    if cfg.family == "audio":
        out["frames"] = jax.ShapeDtypeStruct(
            (shape.batch, cfg.n_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        out["patches"] = jax.ShapeDtypeStruct(
            (shape.batch, cfg.n_patches, cfg.d_model), jnp.float32)
    return out


def params_specs(api: ModelAPI) -> object:
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(api.init, rng)


def decode_state_specs(api: ModelAPI, params_spec, shape: ShapeSpec):
    """Decode-state ShapeDtypeStructs via eval_shape (no allocation)."""
    tok = {"tokens": jax.ShapeDtypeStruct((shape.batch, 1), jnp.int32)}
    if api.cfg.family == "audio":
        tok["frames"] = jax.ShapeDtypeStruct(
            (shape.batch, api.cfg.n_frames, api.cfg.d_model), jnp.float32)
    if api.cfg.family == "vlm":
        tok["patches"] = jax.ShapeDtypeStruct(
            (shape.batch, api.cfg.n_patches, api.cfg.d_model), jnp.float32)
    return jax.eval_shape(
        lambda p, b: api.decode_init(p, b, shape.seq), params_spec, tok)


def token_spec(shape: ShapeSpec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((shape.batch,), jnp.int32)
