"""Training launcher: sharded train_step builder + fault-tolerant loop.

``make_train_step`` builds the jitted (params, opt, batch) -> (params, opt,
metrics) step with the sharding rules from :mod:`repro.parallel.sharding`;
``run`` drives it with checkpoint/restore, auto-resume, a straggler
watchdog, and optional gradient compression.

Usage (example end-to-end driver, ~100M model):
    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 300 --batch 8 --seq 256 --reduced
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as cfglib
from repro.checkpoint.manager import CheckpointManager
from repro.data.tokens import synthetic_batches
from repro.launch.mesh import make_host_mesh
from repro.models.registry import ModelAPI, build
from repro.optim import adamw
from repro.optim.compression import compress_grads
from repro.parallel import sharding as sh


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep: int = 3
    grad_compression: str = "none"   # none | int8
    straggler_factor: float = 3.0    # step-time watchdog threshold
    opt: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig)


def make_train_step(api: ModelAPI, opt_cfg: adamw.AdamWConfig,
                    compression: str = "none") -> Callable:
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(api.loss)(params, batch)
        if compression != "none":
            grads = compress_grads(grads, compression)
        params, opt_state, info = adamw.update(opt_cfg, grads, opt_state,
                                               params)
        metrics = dict(loss=loss, grad_norm=info["grad_norm"],
                       lr=info["lr"])
        return params, opt_state, metrics

    return step


def shard_train_fns(api: ModelAPI, mesh, params, opt_state, batch,
                    opt_cfg, compression="none"):
    """jit the step with explicit in/out shardings + donation."""
    p_spec = sh.params_pspecs(params, mesh)
    o_spec = adamw.AdamWState(step=P(), m=p_spec, v=p_spec)
    b_spec = sh.batch_pspecs(batch, mesh)
    s = lambda t: jax.tree_util.tree_map(
        lambda q: NamedSharding(mesh, q), t,
        is_leaf=lambda x: isinstance(x, P))
    step = jax.jit(
        make_train_step(api, opt_cfg, compression),
        in_shardings=(s(p_spec), s(o_spec), s(b_spec)),
        out_shardings=(s(p_spec), s(o_spec), None),
        donate_argnums=(0, 1))
    return step, (p_spec, o_spec, b_spec)


class StragglerWatchdog:
    """EWMA step-time monitor — flags steps that exceed factor×mean.

    On real fleets this feeds the controller that re-schedules slow hosts;
    here it logs and counts (exercised by tests with an injected delay)."""

    def __init__(self, factor: float = 3.0):
        self.factor = factor
        self.ewma: Optional[float] = None
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        slow = self.ewma is not None and dt > self.factor * self.ewma
        self.ewma = dt if self.ewma is None else 0.9 * self.ewma + 0.1 * dt
        if slow:
            self.flagged += 1
        return slow


def run(api: ModelAPI, train_cfg: TrainConfig, mesh=None,
        batch_size: int = 8, seq: int = 256, seed: int = 0,
        data_iter=None, verbose: bool = True) -> dict:
    """Fault-tolerant training loop with auto-resume."""
    mesh = mesh or make_host_mesh()
    rng = jax.random.PRNGKey(seed)
    params = api.init(rng)
    opt_state = adamw.init(params)
    data_iter = data_iter or synthetic_batches(api.cfg, batch_size, seq,
                                               seed=seed)
    first = next(data_iter)
    step_fn, _ = shard_train_fns(api, mesh, params, opt_state, first,
                                 train_cfg.opt, train_cfg.grad_compression)

    ckpt = CheckpointManager(train_cfg.ckpt_dir, keep=train_cfg.keep)
    start = 0
    restored = ckpt.restore_latest((params, opt_state))
    if restored is not None:
        (params, opt_state), start = restored
        if verbose:
            print(f"[train] resumed from step {start}")

    dog = StragglerWatchdog(train_cfg.straggler_factor)
    losses = []
    t_step = time.perf_counter()
    batch = first
    for i in range(start, train_cfg.steps):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        batch = next(data_iter)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.perf_counter() - t_step
        t_step = time.perf_counter()
        if dog.observe(dt) and verbose:
            print(f"[train] straggler step {i}: {dt * 1e3:.0f} ms")
        if verbose and (i % train_cfg.log_every == 0
                        or i == train_cfg.steps - 1):
            print(f"[train] step {i:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt * 1e3:.0f} ms")
        if (i + 1) % train_cfg.ckpt_every == 0 or i == train_cfg.steps - 1:
            ckpt.save((params, opt_state), step=i + 1)
    return dict(losses=losses, params=params, opt_state=opt_state,
                straggler_flags=dog.flagged)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--compression", default="none")
    args = ap.parse_args(argv)
    cfg = (cfglib.get_reduced(args.arch) if args.reduced
           else cfglib.get(args.arch))
    api = build(cfg)
    tc = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                     grad_compression=args.compression)
    out = run(api, tc, batch_size=args.batch, seq=args.seq)
    print(f"final loss: {out['losses'][-1]:.4f}  "
          f"(first {out['losses'][0]:.4f})")


if __name__ == "__main__":
    main()
