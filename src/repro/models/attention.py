"""Grouped-query attention: training (full/windowed causal) and decode.

Shapes follow the [batch, seq, heads, head_dim] convention.  KV heads are
repeated to query heads with a reshape-free einsum grouping so that GQA costs
no extra HBM.  The Pallas flash kernel (:mod:`repro.kernels.flash_attention`)
is a drop-in replacement for `_sdpa_train` on TPU; the jnp path is used for
CPU smoke tests and the dry-run lowering.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig, apply_rope, dense_init

NEG_INF = -1e30


class AttnParams(NamedTuple):
    wq: jax.Array     # [D, H, hd]
    wk: jax.Array     # [D, KV, hd]
    wv: jax.Array     # [D, KV, hd]
    wo: jax.Array     # [H, hd, D]


def init_attn(key, cfg: ArchConfig, dtype=None) -> AttnParams:
    dtype = dtype or cfg.dtype
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return AttnParams(
        wq=dense_init(k1, (d, h, hd), in_axis=0, dtype=dtype),
        wk=dense_init(k2, (d, kv, hd), in_axis=0, dtype=dtype),
        wv=dense_init(k3, (d, kv, hd), in_axis=0, dtype=dtype),
        wo=dense_init(k4, (h, hd, d), in_axis=0, dtype=dtype),
    )


def _group_heads(q: jax.Array, n_kv: int) -> jax.Array:
    """[B,S,H,hd] -> [B,S,KV,G,hd] grouping query heads per KV head."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, hd)


def _sdpa_naive(q, k, v, *, causal: bool, window: int = 0,
                q_offset: int = 0):
    """Grouped SDPA materializing the full [Sq, Sk] logits (baseline).

    q: [B,Sq,H,hd]; k,v: [B,Sk,KV,hd].  fp32 softmax accumulation.
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    qg = _group_heads(q, kvh)                                # [B,Sq,KV,G,hd]
    scale = hd ** -0.5
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return out.reshape(b, sq, h, hd)


def _sdpa_chunked(q, k, v, *, causal: bool, window: int = 0,
                  q_offset: int = 0, chunk: int = 1024):
    """Flash-style streaming SDPA: online softmax over KV chunks.

    The jnp twin of the Pallas flash kernel — peak live memory per layer is
    one [Sq, chunk] logits block instead of [Sq, Sk], which converts the
    memory-bound baseline into a compute-bound program (EXPERIMENTS §Perf).
    Fully unrolled over chunks so cost_analysis accounting stays exact.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    chunk = min(chunk, sk)
    assert sk % chunk == 0
    nc = sk // chunk
    qg = _group_heads(q, kvh).astype(jnp.float32)
    scale = hd ** -0.5
    qpos = jnp.arange(sq) + q_offset

    m = jnp.full((b, kvh, h // kvh, sq), NEG_INF, jnp.float32)
    l = jnp.zeros((b, kvh, h // kvh, sq), jnp.float32)
    acc = jnp.zeros((b, kvh, h // kvh, sq, hd), jnp.float32)
    q_last = sq - 1 + q_offset            # static: q_offset is a python int
    for c in range(nc):
        if causal and c * chunk > q_last:
            continue                      # fully-masked chunk: skip
        kc = jax.lax.dynamic_slice_in_dim(k, c * chunk, chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, c * chunk, chunk, axis=1)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kc.astype(jnp.float32),
                       preferred_element_type=jnp.float32) * scale
        kpos = c * chunk + jnp.arange(chunk)
        mask = jnp.ones((sq, chunk), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window:
            mask &= qpos[:, None] - kpos[None, :] < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p, vc.astype(jnp.float32))
        m = m_new
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def _sdpa_train(q, k, v, *, causal: bool, window: int = 0,
                q_offset: int = 0, impl: str = "naive", chunk: int = 1024):
    if impl == "chunked" and k.shape[1] > chunk:
        return _sdpa_chunked(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, chunk=chunk)
    return _sdpa_naive(q, k, v, causal=causal, window=window,
                       q_offset=q_offset)


def attention_train(params: AttnParams, x: jax.Array, cfg: ArchConfig,
                    *, causal: bool = True, window: int = 0,
                    pos: Optional[jax.Array] = None,
                    use_rope: bool = True) -> jax.Array:
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params.wq)
    k = jnp.einsum("bsd,dhk->bshk", x, params.wk)
    v = jnp.einsum("bsd,dhk->bshk", x, params.wv)
    if use_rope:
        if pos is None:
            pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    o = _sdpa_train(q, k, v, causal=causal, window=window,
                    impl=cfg.attn_impl, chunk=cfg.attn_chunk)
    return jnp.einsum("bshk,hkd->bsd", o, params.wo)


def cross_attention(params: AttnParams, x: jax.Array, kv_src: jax.Array,
                    cfg: ArchConfig) -> jax.Array:
    """Encoder-decoder cross attention (no mask, no rope)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params.wq)
    k = jnp.einsum("bsd,dhk->bshk", kv_src, params.wk)
    v = jnp.einsum("bsd,dhk->bshk", kv_src, params.wv)
    o = _sdpa_train(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", o, params.wo)


class KVCache(NamedTuple):
    """Decode-time KV cache for one attention layer (or stacked [L, ...])."""
    k: jax.Array         # [B, S_max, KV, hd]
    v: jax.Array         # [B, S_max, KV, hd]

    @staticmethod
    def init(cfg: ArchConfig, batch: int, s_max: int,
             dtype=None, layers: Optional[int] = None) -> "KVCache":
        dtype = dtype or cfg.dtype
        shape = (batch, s_max, cfg.n_kv_heads, cfg.hd)
        if layers is not None:
            shape = (layers,) + shape
        return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def attention_decode(params: AttnParams, x: jax.Array, cache: KVCache,
                     pos: jax.Array, cfg: ArchConfig,
                     *, window: int = 0, use_rope: bool = True):
    """One-token decode step.  x: [B, 1, D]; pos: [] current position.

    Returns (out [B,1,D], updated cache).  The new K/V is scattered into
    the ring position ``pos`` (or ``pos % window`` for local attention).
    """
    b = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, params.wq)
    k = jnp.einsum("bsd,dhk->bshk", x, params.wk)
    v = jnp.einsum("bsd,dhk->bshk", x, params.wv)
    if use_rope:
        p = jnp.broadcast_to(pos[None], (b, 1))
        q = apply_rope(q, p, cfg.rope_theta)
        k = apply_rope(k, p, cfg.rope_theta)
    s_max = cache.k.shape[1]
    slot = (pos % s_max).astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)

    kvh = ck.shape[2]
    qg = _group_heads(q, kvh)                               # [B,1,KV,G,hd]
    scale = cfg.hd ** -0.5
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, ck,
                        preferred_element_type=jnp.float32) * scale
    # ring-buffer aware positions: slot j currently holds absolute position
    # pos - ((pos - j) mod s_max); entries "from the future" are invalid.
    kpos = jnp.arange(s_max)
    abs_pos = pos - ((pos - kpos) % s_max)
    valid = abs_pos >= 0
    if window:
        valid &= abs_pos > pos - window
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", probs, cv)
    o = o.reshape(b, 1, cfg.n_heads, cfg.hd)
    out = jnp.einsum("bshk,hkd->bsd", o, params.wo)
    return out, KVCache(k=ck, v=cv)
