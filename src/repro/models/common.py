"""Shared model components: config, norms, rotary embeddings, init.

All models are pure functions over parameter pytrees (dicts of jnp arrays)
— no framework dependency — so they compose directly with pjit/shard_map
and the sharding rules in :mod:`repro.parallel.sharding`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One unified config covering every assigned architecture family."""

    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                      # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # derived when 0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 1
    n_shared_experts: int = 0
    shared_expert_ff: int = 0
    moe_every: int = 1                # MoE layer stride (1 = every layer)
    capacity_factor: float = 1.25
    # --- recurrent / hybrid ---
    rwkv_head_dim: int = 64
    rg_lru_width: int = 0             # RG-LRU hidden width (0 => d_model)
    conv_width: int = 4
    window: int = 2048                # local-attention window (hybrid)
    attn_every: int = 3               # hybrid pattern: 1 attn per N blocks
    # --- enc-dec (audio) ---
    n_enc_layers: int = 0
    n_frames: int = 1500              # stubbed audio frame embeddings
    # --- vlm ---
    n_patches: int = 256              # stubbed vision patch embeddings
    # --- common ---
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16
    # full-attention archs cannot run the 500k-token cell (DESIGN.md §6)
    subquadratic: bool = False
    # --- lowering / perf knobs (see EXPERIMENTS.md §Perf) ---
    # unroll layer loops: exact cost_analysis accounting (XLA counts while
    # bodies once) and lets XLA schedule across layer boundaries
    unroll_layers: bool = False
    # "naive" materializes [S,S] logits; "chunked" streams KV blocks with an
    # online softmax (the jnp twin of the Pallas flash kernel)
    attn_impl: str = "naive"
    attn_chunk: int = 1024
    # pad the expert count up to a multiple of 16 so EP shards the expert
    # dim instead of falling back to per-expert FF sharding (qwen: 60->64)
    moe_pad_experts: bool = False
    # process the WKV recurrence in chunks of this many tokens: state HBM
    # traffic drops ~chunk x (0 = per-token scan)
    rwkv_chunk: int = 0

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def reduced(self, **overrides) -> "ArchConfig":
        """A smoke-test-sized config of the same family (CPU friendly)."""
        small = dict(
            n_layers=min(self.n_layers, 2),
            d_model=min(self.d_model, 64),
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=min(self.d_ff, 128),
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, 4),
            n_shared_experts=min(self.n_shared_experts, 1),
            shared_expert_ff=min(self.shared_expert_ff, 128),
            n_enc_layers=min(self.n_enc_layers, 2),
            n_frames=min(self.n_frames, 32),
            n_patches=min(self.n_patches, 8),
            window=min(self.window, 32),
            rg_lru_width=min(self.rg_lru_width, 64) if self.rg_lru_width
            else 0,
            rwkv_head_dim=min(self.rwkv_head_dim, 16),
            head_dim=0,
            dtype=jnp.float32,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


# --------------------------------------------------------------------------
# numerics
# --------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(dt)


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; pos: [..., S] absolute positions."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # [hd/2]
    ang = pos[..., None].astype(jnp.float32) * freqs       # [..., S, hd/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def dense_init(key, shape, in_axis: int = -2, dtype=jnp.bfloat16):
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE in fp32. logits [..., V], labels int [...]."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(nll)
