"""Mixture-of-Experts FFN with capacity-bounded token dispatch.

MaxText-style dense dispatch: tokens are routed top-k, each expert processes
a fixed capacity ``C`` of tokens (static shapes — TPU friendly), and the
expert einsums batch over the expert dimension so that sharding the leading
``E`` axis over the ``model`` mesh axis gives expert parallelism (EP) with
an all-to-all-free one-hot dispatch (XLA lowers the combine to reduce
-scatter/all-gather pairs on the EP axis).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig, dense_init


class MoEParams(NamedTuple):
    router: jax.Array        # [D, E]
    w_gate: jax.Array        # [E, D, F]
    w_up: jax.Array          # [E, D, F]
    w_down: jax.Array        # [E, F, D]
    shared_gate: Optional[jax.Array]   # [D, Fs] or None
    shared_up: Optional[jax.Array]
    shared_down: Optional[jax.Array]   # [Fs, D]


def padded_experts(cfg: ArchConfig) -> int:
    """Expert-array size: padded to a multiple of 16 when the EP knob is on
    (padded experts receive no tokens — the router stays at n_experts)."""
    if cfg.moe_pad_experts:
        return -(-cfg.n_experts // 16) * 16
    return cfg.n_experts


def init_moe(key, cfg: ArchConfig, dtype=None) -> MoEParams:
    dtype = dtype or cfg.dtype
    d, e, f = cfg.d_model, padded_experts(cfg), cfg.d_ff
    ks = jax.random.split(key, 7)
    fs = cfg.shared_expert_ff or (cfg.n_shared_experts * f)
    shared = cfg.n_shared_experts > 0
    return MoEParams(
        router=dense_init(ks[0], (d, cfg.n_experts), in_axis=0,
                          dtype=jnp.float32),
        w_gate=dense_init(ks[1], (e, d, f), in_axis=1, dtype=dtype),
        w_up=dense_init(ks[2], (e, d, f), in_axis=1, dtype=dtype),
        w_down=dense_init(ks[3], (e, f, d), in_axis=1, dtype=dtype),
        shared_gate=dense_init(ks[4], (d, fs), in_axis=0, dtype=dtype)
        if shared else None,
        shared_up=dense_init(ks[5], (d, fs), in_axis=0, dtype=dtype)
        if shared else None,
        shared_down=dense_init(ks[6], (fs, d), in_axis=0, dtype=dtype)
        if shared else None,
    )


def moe_ffn(params: MoEParams, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """x: [B, S, D] -> [B, S, D].  Top-k routing with capacity dropping."""
    b, s, d = x.shape
    n = b * s
    e, k = padded_experts(cfg), cfg.top_k
    cap = max(1, int(cfg.capacity_factor * n * k / cfg.n_experts))

    xt = x.reshape(n, d)
    logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32), params.router)
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)                 # [n, k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) in its expert's queue (padded
    # experts — indices >= n_experts — never appear in topi)
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.int32)    # [n, k, e]
    pos_in_e = (jnp.cumsum(onehot.reshape(n * k, e), axis=0)
                .reshape(n, k, e) * onehot).sum(-1) - 1  # [n, k]
    keep = pos_in_e < cap
    # dispatch tensor [n, k] -> scatter into [e, cap]
    flat_e = topi.reshape(-1)
    flat_pos = jnp.where(keep, pos_in_e, cap).reshape(-1)   # cap = dropped
    token_id = jnp.broadcast_to(jnp.arange(n)[:, None], (n, k)).reshape(-1)

    slots = jnp.zeros((e, cap + 1), jnp.int32).at[flat_e, flat_pos].set(
        token_id + 1, mode="drop")[:, :cap]              # 0 = empty slot
    occupied = slots > 0
    gather_ids = jnp.maximum(slots - 1, 0)               # [e, cap]
    xe = xt[gather_ids] * occupied[..., None]            # [e, cap, d]

    h = jnp.einsum("ecd,edf->ecf", xe, params.w_gate)
    u = jnp.einsum("ecd,edf->ecf", xe, params.w_up)
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, params.w_down)

    # combine: scatter expert outputs back with gate weights
    gate_flat = jnp.where(keep, topv, 0.0).reshape(-1)
    wsl = jnp.zeros((e, cap + 1), y.dtype).at[flat_e, flat_pos].set(
        gate_flat.astype(y.dtype), mode="drop")[:, :cap]
    out = jnp.zeros((n + 1, d), y.dtype).at[slots.reshape(-1)].add(
        (y * wsl[..., None]).reshape(e * cap, d), mode="drop")[1:]

    if params.shared_gate is not None:
        hs = jnp.einsum("nd,df->nf", xt, params.shared_gate)
        us = jnp.einsum("nd,df->nf", xt, params.shared_up)
        out = out + jnp.einsum("nf,fd->nd", jax.nn.silu(hs) * us,
                               params.shared_down)
    return out.reshape(b, s, d).astype(x.dtype)


def aux_load_balance_loss(x: jax.Array, params: MoEParams,
                          cfg: ArchConfig) -> jax.Array:
    """Switch-style load-balancing auxiliary loss."""
    n = x.shape[0] * x.shape[1]
    logits = jnp.einsum("nd,de->ne",
                        x.reshape(n, -1).astype(jnp.float32), params.router)
    gates = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(gates, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts), axis=0)
    prob = jnp.mean(gates, axis=0)
    return cfg.n_experts * jnp.sum(frac * prob)
