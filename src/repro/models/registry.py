"""Model registry: one uniform API over all assigned architecture families.

``build(cfg)`` returns a :class:`ModelAPI` with

* ``init(rng)``                      -> params
* ``loss(params, batch)``            -> scalar train loss
* ``decode_init(params, batch, s)``  -> decode state (KV cache / recurrent)
* ``decode_step(params, state, tok)``-> (logits, state)
* ``prefill(params, batch, s)``      -> (logits, state)   (where meaningful)

``batch`` is a dict: always ``tokens`` [B, S]; plus ``frames`` [B, T, D]
(audio stub) or ``patches`` [B, P, D] (vlm stub).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import rglru, rwkv6, transformer, whisper
from repro.models.common import ArchConfig


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ArchConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[[Any, dict], jax.Array]
    decode_init: Callable[[Any, dict, int], Any]
    decode_step: Callable[[Any, Any, jax.Array], tuple]
    prefill: Optional[Callable[[Any, dict, int], tuple]] = None


def _transformer_api(cfg: ArchConfig) -> ModelAPI:
    prefix_key = {"vlm": "patches"}.get(cfg.family)

    def loss(params, batch):
        pe = batch.get(prefix_key) if prefix_key else None
        return transformer.lm_loss(params, batch["tokens"], cfg,
                                   prefix_embed=pe)

    def decode_init(params, batch, s_max):
        b = batch["tokens"].shape[0]
        st = transformer.init_decode(cfg, b, s_max)
        return st

    def decode_step(params, st, token):
        return transformer.decode_step(params, st, token, cfg)

    def prefill(params, batch, s_max):
        return transformer.prefill(params, batch["tokens"], cfg, s_max)

    return ModelAPI(cfg=cfg,
                    init=lambda rng: transformer.init_lm(rng, cfg),
                    loss=loss, decode_init=decode_init,
                    decode_step=decode_step, prefill=prefill)


def _rwkv_api(cfg: ArchConfig) -> ModelAPI:
    def decode_init(params, batch, s_max):
        return rwkv6.init_state(cfg, batch["tokens"].shape[0])

    return ModelAPI(
        cfg=cfg,
        init=lambda rng: rwkv6.init_rwkv(rng, cfg),
        loss=lambda p, b: rwkv6.lm_loss(p, b["tokens"], cfg),
        decode_init=decode_init,
        decode_step=lambda p, st, t: rwkv6.decode_step(p, st, t, cfg))


def _griffin_api(cfg: ArchConfig) -> ModelAPI:
    def decode_init(params, batch, s_max):
        return rglru.init_state(cfg, batch["tokens"].shape[0])

    return ModelAPI(
        cfg=cfg,
        init=lambda rng: rglru.init_griffin(rng, cfg),
        loss=lambda p, b: rglru.lm_loss(p, b["tokens"], cfg),
        decode_init=decode_init,
        decode_step=lambda p, st, t: rglru.decode_step(p, st, t, cfg))


def _whisper_api(cfg: ArchConfig) -> ModelAPI:
    max_pos = 33_024   # covers train_4k and decode_32k target positions

    def decode_init(params, batch, s_max):
        return whisper.init_decode(params, batch["frames"], cfg, s_max)

    return ModelAPI(
        cfg=cfg,
        init=lambda rng: whisper.init_whisper(rng, cfg, max_pos=max_pos),
        loss=lambda p, b: whisper.loss(p, b["frames"], b["tokens"], cfg),
        decode_init=decode_init,
        decode_step=lambda p, st, t: whisper.decode_step(p, st, t, cfg))


def build(cfg: ArchConfig) -> ModelAPI:
    if cfg.family in ("dense", "moe", "vlm"):
        return _transformer_api(cfg)
    if cfg.family == "ssm":
        return _rwkv_api(cfg)
    if cfg.family == "hybrid":
        return _griffin_api(cfg)
    if cfg.family == "audio":
        return _whisper_api(cfg)
    raise ValueError(f"unknown family: {cfg.family}")


def make_batch(cfg: ArchConfig, batch: int, seq: int, rng=None) -> dict:
    """A synthetic batch of the right structure (tests/examples)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(rng)
    out = {"tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab,
                                        jnp.int32)}
    if cfg.family == "audio":
        out["frames"] = jax.random.normal(
            k2, (batch, cfg.n_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            k2, (batch, cfg.n_patches, cfg.d_model), jnp.float32)
    return out
