"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU recurrent blocks
interleaved with local (sliding-window) attention at a 1:2 ratio.

The RG-LRU recurrence ``h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t x_t)`` is a
linear recurrence, so training uses ``jax.lax.associative_scan`` (O(log S)
depth); decode keeps an O(1) state — ``long_500k`` runs for this arch.
Layers follow the repeating super-block (recurrent, recurrent, local-attn);
super-blocks are stacked and scanned to keep the lowered HLO small.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models.common import (ArchConfig, cross_entropy, dense_init,
                                 embed_init, rms_norm, split_keys)

LRU_C = 8.0   # Griffin's fixed exponent scale


class GLUParams(NamedTuple):
    w_gate: jax.Array
    w_up: jax.Array
    w_down: jax.Array


class RecurrentBlock(NamedTuple):
    ln: jax.Array
    w_x: jax.Array        # [D, R] input branch
    w_y: jax.Array        # [D, R] gate branch
    conv_w: jax.Array     # [4, R] depthwise causal conv
    conv_b: jax.Array     # [R]
    lam: jax.Array        # [R] RG-LRU Λ
    w_a: jax.Array        # [R, R] recurrence gate
    b_a: jax.Array        # [R]
    w_i: jax.Array        # [R, R] input gate
    b_i: jax.Array        # [R]
    w_o: jax.Array        # [R, D]
    ln_mlp: jax.Array
    mlp: GLUParams


class AttnBlock(NamedTuple):
    ln: jax.Array
    attn: A.AttnParams
    ln_mlp: jax.Array
    mlp: GLUParams


class SuperBlock(NamedTuple):
    rec1: RecurrentBlock
    rec2: RecurrentBlock
    attn: AttnBlock


class GriffinParams(NamedTuple):
    embed: jax.Array
    supers: SuperBlock       # stacked [n_super, ...]
    tail: RecurrentBlock     # stacked [n_tail, ...] leftover rec layers
    ln_f: jax.Array


def n_super(cfg: ArchConfig) -> int:
    return cfg.n_layers // 3


def n_tail(cfg: ArchConfig) -> int:
    return cfg.n_layers % 3


def _init_glu(key, d, f, dt) -> GLUParams:
    k1, k2, k3 = jax.random.split(key, 3)
    return GLUParams(
        w_gate=dense_init(k1, (d, f), in_axis=0, dtype=dt),
        w_up=dense_init(k2, (d, f), in_axis=0, dtype=dt),
        w_down=dense_init(k3, (f, d), in_axis=0, dtype=dt))


def _init_rec(key, cfg: ArchConfig) -> RecurrentBlock:
    d, dt = cfg.d_model, cfg.dtype
    r = cfg.rg_lru_width or d
    ks = split_keys(key, 7)
    return RecurrentBlock(
        ln=jnp.zeros((d,), dt),
        w_x=dense_init(ks[0], (d, r), in_axis=0, dtype=dt),
        w_y=dense_init(ks[1], (d, r), in_axis=0, dtype=dt),
        conv_w=dense_init(ks[2], (cfg.conv_width, r), in_axis=0, dtype=dt),
        conv_b=jnp.zeros((r,), dt),
        lam=jnp.full((r,), 2.0, jnp.float32),   # a ≈ 0.88^8 decay at init
        w_a=dense_init(ks[3], (r, r), in_axis=0, dtype=dt),
        b_a=jnp.zeros((r,), dt),
        w_i=dense_init(ks[4], (r, r), in_axis=0, dtype=dt),
        b_i=jnp.zeros((r,), dt),
        w_o=dense_init(ks[5], (r, d), in_axis=0, dtype=dt),
        ln_mlp=jnp.zeros((d,), dt),
        mlp=_init_glu(ks[6], d, cfg.d_ff, dt))


def _init_attn_block(key, cfg: ArchConfig) -> AttnBlock:
    k1, k2 = jax.random.split(key)
    return AttnBlock(
        ln=jnp.zeros((cfg.d_model,), cfg.dtype),
        attn=A.init_attn(k1, cfg),
        ln_mlp=jnp.zeros((cfg.d_model,), cfg.dtype),
        mlp=_init_glu(k2, cfg.d_model, cfg.d_ff, cfg.dtype))


def init_griffin(key, cfg: ArchConfig) -> GriffinParams:
    kt, ks_, ktl = jax.random.split(key, 3)

    def one_super(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return SuperBlock(rec1=_init_rec(k1, cfg), rec2=_init_rec(k2, cfg),
                          attn=_init_attn_block(k3, cfg))

    supers = jax.vmap(one_super)(jax.random.split(ks_, n_super(cfg)))
    tail = jax.vmap(lambda k: _init_rec(k, cfg))(
        jax.random.split(ktl, max(n_tail(cfg), 1)))
    return GriffinParams(
        embed=embed_init(kt, (cfg.vocab, cfg.d_model), cfg.dtype),
        supers=supers, tail=tail,
        ln_f=jnp.zeros((cfg.d_model,), cfg.dtype))


def _glu(p: GLUParams, x):
    return jnp.einsum(
        "bsf,fd->bsd",
        jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p.w_gate))
        * jnp.einsum("bsd,df->bsf", x, p.w_up), p.w_down)


# --------------------------------------------------------------------------
# RG-LRU
# --------------------------------------------------------------------------

def _rglru_scan(a: jax.Array, b: jax.Array, h0=None):
    """h_t = a_t h_{t-1} + b_t along axis 1 via associative scan (fp32)."""
    if h0 is not None:
        # fold the carried state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def _rec_train(p: RecurrentBlock, x: jax.Array) -> jax.Array:
    """x: [B,S,D] -> [B,S,D]; full-sequence recurrent branch."""
    xb = jnp.einsum("bsd,dr->bsr", x, p.w_x)
    yb = jnp.einsum("bsd,dr->bsr", x, p.w_y)
    # causal depthwise conv (width W)
    w = p.conv_w
    c = sum(jnp.pad(xb, ((0, 0), (i, 0), (0, 0)))[:, :xb.shape[1]]
            * w[w.shape[0] - 1 - i][None, None, :]
            for i in range(w.shape[0])) + p.conv_b
    r = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", c, p.w_a)
                       + p.b_a).astype(jnp.float32)
    i = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", c, p.w_i) + p.b_i)
    log_a = -LRU_C * jax.nn.softplus(p.lam) * r          # fp32
    a = jnp.exp(log_a)
    gated = (jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
             * (i * c).astype(jnp.float32))
    h = _rglru_scan(a, gated)
    out = (h.astype(x.dtype) * jax.nn.gelu(yb))
    return jnp.einsum("bsr,rd->bsd", out, p.w_o)


class RecState(NamedTuple):
    conv: jax.Array     # [B, W-1, R] last inputs
    h: jax.Array        # [B, R] fp32


def _rec_decode(p: RecurrentBlock, x: jax.Array, st: RecState):
    """x: [B,1,D] one token."""
    xb = jnp.einsum("bsd,dr->bsr", x, p.w_x)[:, 0]       # [B,R]
    yb = jnp.einsum("bsd,dr->bsr", x, p.w_y)[:, 0]
    w = p.conv_w
    hist = jnp.concatenate([st.conv, xb[:, None]], axis=1)   # [B,W,R]
    c = jnp.einsum("bwr,wr->br", hist, w) + p.conv_b
    r = jax.nn.sigmoid(c @ p.w_a + p.b_a).astype(jnp.float32)
    i = jax.nn.sigmoid(c @ p.w_i + p.b_i)
    log_a = -LRU_C * jax.nn.softplus(p.lam) * r
    a = jnp.exp(log_a)
    h = a * st.h + jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2 * log_a), 1e-12)) \
        * (i * c).astype(jnp.float32)
    out = (h.astype(x.dtype) * jax.nn.gelu(yb)) @ p.w_o
    return out[:, None], RecState(conv=hist[:, 1:], h=h)


# --------------------------------------------------------------------------
# full model
# --------------------------------------------------------------------------

def _rec_block_train(p: RecurrentBlock, x, cfg):
    x = x + _rec_train(p, rms_norm(x, p.ln, cfg.norm_eps))
    x = x + _glu(p.mlp, rms_norm(x, p.ln_mlp, cfg.norm_eps))
    return x


def _attn_block_train(p: AttnBlock, x, cfg):
    x = x + A.attention_train(p.attn, rms_norm(x, p.ln, cfg.norm_eps), cfg,
                              causal=True, window=cfg.window)
    x = x + _glu(p.mlp, rms_norm(x, p.ln_mlp, cfg.norm_eps))
    return x


def forward(params: GriffinParams, tokens: jax.Array, cfg: ArchConfig):
    x = params.embed[tokens].astype(cfg.dtype)

    def body(x, sb: SuperBlock):
        x = _rec_block_train(sb.rec1, x, cfg)
        x = _rec_block_train(sb.rec2, x, cfg)
        x = _attn_block_train(sb.attn, x, cfg)
        return x, None

    body_fn = jax.checkpoint(lambda c, sb: body(c, sb))
    if cfg.unroll_layers:
        for i in range(n_super(cfg)):
            sb = jax.tree_util.tree_map(lambda a, i=i: a[i], params.supers)
            x, _ = body_fn(x, sb)
    else:
        x, _ = jax.lax.scan(body_fn, x, params.supers)
    for i in range(n_tail(cfg)):
        tl = jax.tree_util.tree_map(lambda a, i=i: a[i], params.tail)
        x = _rec_block_train(tl, x, cfg)
    x = rms_norm(x, params.ln_f, cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", x,
                      params.embed.T.astype(cfg.dtype))


def lm_loss(params: GriffinParams, tokens: jax.Array, cfg: ArchConfig):
    logits = forward(params, tokens, cfg)
    return cross_entropy(logits[:, :-1], tokens[:, 1:])


class GriffinState(NamedTuple):
    rec1: RecState        # stacked [n_super, ...]
    rec2: RecState
    attn: A.KVCache       # stacked [n_super, B, window, KV, hd]
    tail: RecState        # stacked [n_tail, ...]
    pos: jax.Array


def init_state(cfg: ArchConfig, batch: int) -> GriffinState:
    r = cfg.rg_lru_width or cfg.d_model
    ns, nt = n_super(cfg), max(n_tail(cfg), 1)
    mk = lambda n: RecState(
        conv=jnp.zeros((n, batch, cfg.conv_width - 1, r), cfg.dtype),
        h=jnp.zeros((n, batch, r), jnp.float32))
    return GriffinState(
        rec1=mk(ns), rec2=mk(ns),
        attn=A.KVCache.init(cfg, batch, cfg.window, layers=ns),
        tail=mk(nt), pos=jnp.int32(0))


def decode_step(params: GriffinParams, st: GriffinState, token: jax.Array,
                cfg: ArchConfig):
    x = params.embed[token][:, None, :].astype(cfg.dtype)

    def body(x, inp):
        sb, s1, s2, kv = inp
        h = rms_norm(x, sb.rec1.ln, cfg.norm_eps)
        o, s1n = _rec_decode(sb.rec1, h, s1)
        x = x + o
        x = x + _glu(sb.rec1.mlp, rms_norm(x, sb.rec1.ln_mlp, cfg.norm_eps))
        h = rms_norm(x, sb.rec2.ln, cfg.norm_eps)
        o, s2n = _rec_decode(sb.rec2, h, s2)
        x = x + o
        x = x + _glu(sb.rec2.mlp, rms_norm(x, sb.rec2.ln_mlp, cfg.norm_eps))
        h = rms_norm(x, sb.attn.ln, cfg.norm_eps)
        o, kvn = A.attention_decode(sb.attn.attn, h, kv, st.pos, cfg,
                                    window=cfg.window)
        x = x + o
        x = x + _glu(sb.attn.mlp,
                     rms_norm(x, sb.attn.ln_mlp, cfg.norm_eps))
        return x, (s1n, s2n, kvn)

    if cfg.unroll_layers:
        outs = []
        for i in range(n_super(cfg)):
            pick = lambda a, i=i: a[i]
            inp = jax.tree_util.tree_map(
                pick, (params.supers, st.rec1, st.rec2, st.attn))
            x, o = body(x, inp)
            outs.append(o)
        r1, r2, kv = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *outs)
    else:
        x, (r1, r2, kv) = jax.lax.scan(
            body, x, (params.supers, st.rec1, st.rec2, st.attn))

    def tail_body(x, inp):
        tl, s = inp
        h = rms_norm(x, tl.ln, cfg.norm_eps)
        o, sn = _rec_decode(tl, h, s)
        x = x + o
        x = x + _glu(tl.mlp, rms_norm(x, tl.ln_mlp, cfg.norm_eps))
        return x, sn

    if n_tail(cfg):
        x, tail_st = jax.lax.scan(tail_body, x, (params.tail, st.tail))
    else:
        tail_st = st.tail
    x = rms_norm(x[:, 0], params.ln_f, cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x, params.embed.T.astype(cfg.dtype))
    return logits, GriffinState(rec1=r1, rec2=r2, attn=kv, tail=tail_st,
                                pos=st.pos + 1)
