"""RWKV6 "Finch" (arXiv:2404.05892): linear-time LM with data-dependent
decay.  Attention-free — the per-head state is a [N, N] outer-product
accumulator, so decode state is O(1) in sequence length and the
``long_500k`` cell runs (DESIGN.md §6).

Time mixing uses the paper's ddlerp token-shift (low-rank data-dependent
interpolation) and the diagonal data-dependent decay
``w_t = exp(-exp(w0 + lora(x)))``; channel mixing is the squared-ReLU MLP.
Training scans over time (the Pallas chunked kernel in
``repro.kernels.rwkv_scan`` is the TPU fast path for the same recurrence).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import (ArchConfig, cross_entropy, dense_init,
                                 embed_init, layer_norm, split_keys)

TM_LORA = 32      # token-mix lora rank
DW_LORA = 64      # decay lora rank


class RWKVLayer(NamedTuple):
    ln1_s: jax.Array
    ln1_b: jax.Array
    ln2_s: jax.Array
    ln2_b: jax.Array
    # --- time mix ---
    mu_x: jax.Array        # [D]
    mu: jax.Array          # [5, D]  (r, k, v, w, g)
    lora_a: jax.Array      # [D, 5*TM]
    lora_b: jax.Array      # [5, TM, D]
    w0: jax.Array          # [D] decay bias (log-log space)
    w_a: jax.Array         # [D, DW]
    w_b: jax.Array         # [DW, D]
    u: jax.Array           # [H, N] per-head bonus
    wr: jax.Array          # [D, D]
    wk: jax.Array
    wv: jax.Array
    wg: jax.Array
    wo: jax.Array
    lnx_s: jax.Array       # [D] per-head group-norm scale
    lnx_b: jax.Array
    # --- channel mix ---
    mu_ck: jax.Array       # [D]
    mu_cr: jax.Array       # [D]
    wck: jax.Array         # [D, F]
    wcv: jax.Array         # [F, D]
    wcr: jax.Array         # [D, D]


class RWKVParams(NamedTuple):
    embed: jax.Array
    ln0_s: jax.Array
    ln0_b: jax.Array
    layers: RWKVLayer
    lnf_s: jax.Array
    lnf_b: jax.Array
    head: jax.Array


def n_heads(cfg: ArchConfig) -> int:
    return cfg.d_model // cfg.rwkv_head_dim


def init_layer(key, cfg: ArchConfig) -> RWKVLayer:
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.dtype
    h, n = n_heads(cfg), cfg.rwkv_head_dim
    ks = split_keys(key, 12)
    zeros = lambda *s: jnp.zeros(s, dt)
    return RWKVLayer(
        ln1_s=jnp.ones((d,), dt), ln1_b=zeros(d),
        ln2_s=jnp.ones((d,), dt), ln2_b=zeros(d),
        mu_x=zeros(d), mu=jnp.full((5, d), 0.5, dt),
        lora_a=dense_init(ks[0], (d, 5 * TM_LORA), in_axis=0, dtype=dt),
        lora_b=dense_init(ks[1], (5, TM_LORA, d), in_axis=1, dtype=dt),
        w0=jnp.full((d,), -6.0, dt),
        w_a=dense_init(ks[2], (d, DW_LORA), in_axis=0, dtype=dt),
        w_b=dense_init(ks[3], (DW_LORA, d), in_axis=0, dtype=dt),
        u=dense_init(ks[4], (h, n), in_axis=1, dtype=dt),
        wr=dense_init(ks[5], (d, d), in_axis=0, dtype=dt),
        wk=dense_init(ks[6], (d, d), in_axis=0, dtype=dt),
        wv=dense_init(ks[7], (d, d), in_axis=0, dtype=dt),
        wg=dense_init(ks[8], (d, d), in_axis=0, dtype=dt),
        wo=dense_init(ks[9], (d, d), in_axis=0, dtype=dt),
        lnx_s=jnp.ones((d,), dt), lnx_b=zeros(d),
        mu_ck=jnp.full((d,), 0.5, dt), mu_cr=jnp.full((d,), 0.5, dt),
        wck=dense_init(ks[10], (d, f), in_axis=0, dtype=dt),
        wcv=dense_init(ks[11], (f, d), in_axis=0, dtype=dt),
        wcr=dense_init(ks[0], (d, d), in_axis=0, dtype=dt),
    )


def init_rwkv(key, cfg: ArchConfig) -> RWKVParams:
    kt, kl, kh = jax.random.split(key, 3)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(
        jax.random.split(kl, cfg.n_layers))
    d = cfg.d_model
    return RWKVParams(
        embed=embed_init(kt, (cfg.vocab, d), cfg.dtype),
        ln0_s=jnp.ones((d,), cfg.dtype), ln0_b=jnp.zeros((d,), cfg.dtype),
        layers=layers,
        lnf_s=jnp.ones((d,), cfg.dtype), lnf_b=jnp.zeros((d,), cfg.dtype),
        head=dense_init(kh, (d, cfg.vocab), in_axis=0, dtype=cfg.dtype),
    )


class LayerState(NamedTuple):
    """Recurrent state of one layer (stacked [L, ...] for the model)."""
    tm_shift: jax.Array    # [B, D] last token's input to time mix
    cm_shift: jax.Array    # [B, D] last token's input to channel mix
    wkv: jax.Array         # [B, H, N, N] fp32 outer-product state


def init_state(cfg: ArchConfig, batch: int) -> LayerState:
    d, h, n = cfg.d_model, n_heads(cfg), cfg.rwkv_head_dim
    return LayerState(
        tm_shift=jnp.zeros((cfg.n_layers, batch, d), cfg.dtype),
        cm_shift=jnp.zeros((cfg.n_layers, batch, d), cfg.dtype),
        wkv=jnp.zeros((cfg.n_layers, batch, h, n, n), jnp.float32))


def _time_mix_step(lp: RWKVLayer, x, prev_x, s, cfg: ArchConfig):
    """One token of WKV6. x: [B, D]; s: [B, H, N, N] fp32."""
    h, n = n_heads(cfg), cfg.rwkv_head_dim
    b, d = x.shape
    xx = prev_x - x
    xxx = x + xx * lp.mu_x
    lo = jnp.tanh(xxx @ lp.lora_a).reshape(b, 5, TM_LORA)
    dd = jnp.einsum("bft,ftd->fbd", lo, lp.lora_b)       # [5, B, D]
    mix = x[None] + xx[None] * (lp.mu[:, None, :] + dd)  # [5, B, D]
    mr, mk, mv, mw, mg = mix
    r = (mr @ lp.wr).reshape(b, h, n)
    k = (mk @ lp.wk).reshape(b, h, n)
    v = (mv @ lp.wv).reshape(b, h, n)
    g = jax.nn.silu(mg @ lp.wg)
    w = jnp.exp(-jnp.exp((lp.w0 + jnp.tanh(mw @ lp.w_a) @ lp.w_b)
                         .astype(jnp.float32))).reshape(b, h, n)

    r32, k32, v32 = (a.astype(jnp.float32) for a in (r, k, v))
    kv = k32[..., :, None] * v32[..., None, :]           # [B,H,N,N]
    out = jnp.einsum("bhn,bhnm->bhm",
                     r32, s + lp.u.astype(jnp.float32)[None, :, :, None]
                     * kv)
    s_new = w[..., :, None] * s + kv
    out = out.reshape(b, d)
    # per-head group norm
    oh = out.reshape(b, h, n)
    mu = oh.mean(-1, keepdims=True)
    var = oh.var(-1, keepdims=True)
    oh = (oh - mu) * jax.lax.rsqrt(var + 64e-5)
    out = oh.reshape(b, d) * lp.lnx_s.astype(jnp.float32) \
        + lp.lnx_b.astype(jnp.float32)
    out = (out.astype(cfg.dtype) * g) @ lp.wo
    return out, s_new


def _channel_mix_step(lp: RWKVLayer, x, prev_x):
    xx = prev_x - x
    k = x + xx * lp.mu_ck
    r = x + xx * lp.mu_cr
    kk = jnp.square(jax.nn.relu(k @ lp.wck))
    return jax.nn.sigmoid(r @ lp.wcr) * (kk @ lp.wcv)


def _layer_step(lp: RWKVLayer, x, st: LayerState, cfg: ArchConfig):
    """One token through one layer. x: [B, D]."""
    h1 = layer_norm(x, lp.ln1_s, lp.ln1_b)
    tm, wkv = _time_mix_step(lp, h1, st.tm_shift, st.wkv, cfg)
    x = x + tm
    h2 = layer_norm(x, lp.ln2_s, lp.ln2_b)
    cm = _channel_mix_step(lp, h2, st.cm_shift)
    x = x + cm
    return x, LayerState(tm_shift=h1, cm_shift=h2, wkv=wkv)


def _time_mix_seq(lp: RWKVLayer, x: jax.Array, cfg: ArchConfig):
    """Full-sequence WKV6: weights stream ONCE per layer (layer-major).

    All projections are [B,S,D] matmuls; only the state recurrence scans
    over time, and its body is weight-free (elementwise [B,H,N,N]) — the
    formulation real RWKV training uses, and the program the Pallas
    ``rwkv_scan`` kernel replaces on TPU (state held in VMEM).
    """
    b, s, d = x.shape
    h, n = n_heads(cfg), cfg.rwkv_head_dim
    xprev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    xx = xprev - x
    xxx = x + xx * lp.mu_x
    lo = jnp.tanh(jnp.einsum("bsd,dt->bst", xxx, lp.lora_a)
                  ).reshape(b, s, 5, TM_LORA)
    dd = jnp.einsum("bsft,ftd->fbsd", lo, lp.lora_b)      # [5,B,S,D]
    mix = x[None] + xx[None] * (lp.mu[:, None, None, :] + dd)
    mr, mk, mv, mw, mg = mix
    r = jnp.einsum("bsd,de->bse", mr, lp.wr).reshape(b, s, h, n)
    k = jnp.einsum("bsd,de->bse", mk, lp.wk).reshape(b, s, h, n)
    v = jnp.einsum("bsd,de->bse", mv, lp.wv).reshape(b, s, h, n)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", mg, lp.wg))
    w = jnp.exp(-jnp.exp(
        (lp.w0 + jnp.tanh(jnp.einsum("bsd,dt->bst", mw, lp.w_a))
         @ lp.w_b).astype(jnp.float32))).reshape(b, s, h, n)

    r32, k32, v32 = (a.astype(jnp.float32) for a in (r, k, v))
    u32 = lp.u.astype(jnp.float32)

    def token(sstate, rt, kt, vt, wt):
        kv = kt[..., :, None] * vt[..., None, :]  # [B,H,N,N]
        out = jnp.einsum("bhn,bhnm->bhm", rt,
                         sstate + u32[None, :, :, None] * kv)
        return wt[..., :, None] * sstate + kv, out

    chunk = cfg.rwkv_chunk
    if chunk and s % chunk == 0:
        # chunked recurrence: C token updates per scan step fuse into one
        # loop body, so the [B,H,N,N] state round-trips HBM once per chunk
        # instead of once per token (~C x less state traffic)
        def step(sstate, inp):
            rs, ks, vs, ws = inp                  # [C,B,H,N]
            outs = []
            for t in range(chunk):
                sstate, o = token(sstate, rs[t], ks[t], vs[t], ws[t])
                outs.append(o)
            return sstate, jnp.stack(outs)

        xs = tuple(jnp.moveaxis(a, 1, 0).reshape(
            s // chunk, chunk, b, h, n)
            for a in (r32, k32, v32, w.astype(jnp.float32)))
        s0 = jnp.zeros((b, h, n, n), jnp.float32)
        _, out = jax.lax.scan(step, s0, xs)
        out = jnp.moveaxis(out.reshape(s, b, h, n), 0, 1).reshape(b, s, d)
    else:
        def step(sstate, inp):
            return token(sstate, *inp)

        xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r32, k32, v32,
                                                   w.astype(jnp.float32)))
        s0 = jnp.zeros((b, h, n, n), jnp.float32)
        _, out = jax.lax.scan(step, s0, xs)
        out = jnp.moveaxis(out, 0, 1).reshape(b, s, d)
    # per-head group norm
    oh = out.reshape(b, s, h, n)
    mu = oh.mean(-1, keepdims=True)
    var = oh.var(-1, keepdims=True)
    oh = (oh - mu) * jax.lax.rsqrt(var + 64e-5)
    out = oh.reshape(b, s, d) * lp.lnx_s.astype(jnp.float32) \
        + lp.lnx_b.astype(jnp.float32)
    return (out.astype(cfg.dtype) * g) @ lp.wo


def _channel_mix_seq(lp: RWKVLayer, x: jax.Array):
    xprev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    xx = xprev - x
    k = x + xx * lp.mu_ck
    r = x + xx * lp.mu_cr
    kk = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", k, lp.wck)))
    return jax.nn.sigmoid(jnp.einsum("bsd,de->bse", r, lp.wcr)) \
        * jnp.einsum("bsf,fd->bsd", kk, lp.wcv)


def _layer_seq(lp: RWKVLayer, x: jax.Array, cfg: ArchConfig):
    h1 = layer_norm(x, lp.ln1_s, lp.ln1_b)
    x = x + _time_mix_seq(lp, h1, cfg)
    h2 = layer_norm(x, lp.ln2_s, lp.ln2_b)
    x = x + _channel_mix_seq(lp, h2)
    return x


def forward(params: RWKVParams, tokens: jax.Array, cfg: ArchConfig
            ) -> jax.Array:
    """Training forward, layer-major: tokens [B,S] -> logits [B,S,V]."""
    x = params.embed[tokens].astype(cfg.dtype)
    x = layer_norm(x, params.ln0_s, params.ln0_b)

    fn = jax.checkpoint(lambda c, lp: (_layer_seq(lp, c, cfg), None))
    if cfg.unroll_layers:
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a, i=i: a[i], params.layers)
            x, _ = fn(x, lp)
    else:
        x, _ = jax.lax.scan(fn, x, params.layers)
    y = layer_norm(x, params.lnf_s, params.lnf_b)
    return jnp.einsum("bsd,dv->bsv", y, params.head.astype(cfg.dtype))


def lm_loss(params: RWKVParams, tokens: jax.Array, cfg: ArchConfig):
    logits = forward(params, tokens, cfg)
    return cross_entropy(logits[:, :-1], tokens[:, 1:])


def decode_step(params: RWKVParams, st: LayerState, token: jax.Array,
                cfg: ArchConfig):
    """One serving step: token [B] -> logits [B, V], updated state."""
    x = params.embed[token].astype(cfg.dtype)
    x = layer_norm(x, params.ln0_s, params.ln0_b)

    def layer_body(x, inp):
        lp, lst = inp
        return _layer_step(lp, x, lst, cfg)

    if cfg.unroll_layers:
        outs = []
        for i in range(cfg.n_layers):
            pick = lambda a, i=i: a[i]
            inp = jax.tree_util.tree_map(pick, (params.layers, st))
            x, o = layer_body(x, inp)
            outs.append(o)
        y = x
        new_st = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
    else:
        y, new_st = jax.lax.scan(layer_body, x, (params.layers, st))
    y = layer_norm(y, params.lnf_s, params.lnf_b)
    return jnp.einsum("bd,dv->bv", y, params.head.astype(cfg.dtype)), new_st
