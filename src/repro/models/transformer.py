"""Decoder-only transformer LM (dense + MoE), scan-over-layers.

Backbone for: llama4-scout, qwen2-moe, command-r, deepseek-67b, smollm,
granite, the InternVL LM, and the Whisper decoder.  Layers are stacked on a
leading ``L`` axis and driven by ``jax.lax.scan`` — this keeps the HLO small
(one layer body) which matters for the 80-compile dry-run, and pairs with a
remat policy for training.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import moe as M
from repro.models.common import (ArchConfig, cross_entropy, dense_init,
                                 embed_init, rms_norm, split_keys)


class MLPParams(NamedTuple):
    w_gate: jax.Array     # [D, F]
    w_up: jax.Array       # [D, F]
    w_down: jax.Array     # [F, D]


def init_mlp(key, d, f, dtype) -> MLPParams:
    k1, k2, k3 = jax.random.split(key, 3)
    return MLPParams(
        w_gate=dense_init(k1, (d, f), in_axis=0, dtype=dtype),
        w_up=dense_init(k2, (d, f), in_axis=0, dtype=dtype),
        w_down=dense_init(k3, (f, d), in_axis=0, dtype=dtype))


def mlp(params: MLPParams, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, params.w_gate)
    u = jnp.einsum("bsd,df->bsf", x, params.w_up)
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(h) * u, params.w_down)


class LayerParams(NamedTuple):
    ln_attn: jax.Array
    attn: A.AttnParams
    ln_mlp: jax.Array
    mlp: Optional[MLPParams]       # dense layers
    moe: Optional[M.MoEParams]     # MoE layers (None for dense archs)


class LMParams(NamedTuple):
    embed: jax.Array               # [V, D]
    layers: LayerParams            # stacked [L, ...]
    ln_f: jax.Array                # [D]
    lm_head: Optional[jax.Array]   # [D, V] (None when tied)


def init_layer(key, cfg: ArchConfig, dtype=None) -> LayerParams:
    dtype = dtype or cfg.dtype
    ks = split_keys(key, 3)
    d = cfg.d_model
    return LayerParams(
        ln_attn=jnp.zeros((d,), dtype),
        attn=A.init_attn(ks[0], cfg, dtype),
        ln_mlp=jnp.zeros((d,), dtype),
        mlp=None if cfg.is_moe else init_mlp(ks[1], d, cfg.d_ff, dtype),
        moe=M.init_moe(ks[2], cfg, dtype) if cfg.is_moe else None,
    )


def init_lm(key, cfg: ArchConfig) -> LMParams:
    kt, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    return LMParams(
        embed=embed_init(kt, (cfg.vocab, cfg.d_model), cfg.dtype),
        layers=layers,
        ln_f=jnp.zeros((cfg.d_model,), cfg.dtype),
        lm_head=None if cfg.tie_embeddings else
        dense_init(kh, (cfg.d_model, cfg.vocab), in_axis=0,
                   dtype=cfg.dtype),
    )


def _layer_fwd(lp: LayerParams, x: jax.Array, cfg: ArchConfig,
               pos: Optional[jax.Array]) -> jax.Array:
    h = rms_norm(x, lp.ln_attn, cfg.norm_eps)
    x = x + A.attention_train(lp.attn, h, cfg, causal=True, pos=pos)
    h = rms_norm(x, lp.ln_mlp, cfg.norm_eps)
    if cfg.is_moe:
        x = x + M.moe_ffn(lp.moe, h, cfg)
    else:
        x = x + mlp(lp.mlp, h)
    return x


def forward(params: LMParams, tokens: jax.Array, cfg: ArchConfig,
            *, prefix_embed: Optional[jax.Array] = None,
            remat: bool = True) -> jax.Array:
    """tokens [B, S] -> logits [B, S(+P), V].

    ``prefix_embed`` prepends precomputed embeddings (the VLM patch stub).
    """
    x = params.embed[tokens].astype(cfg.dtype)
    if prefix_embed is not None:
        x = jnp.concatenate([prefix_embed.astype(cfg.dtype), x], axis=1)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))

    fn = (jax.checkpoint(_layer_fwd, static_argnums=(2,)) if remat
          else _layer_fwd)
    if cfg.unroll_layers:
        # exact cost accounting + cross-layer scheduling (see common.py)
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a, i=i: a[i], params.layers)
            x = fn(lp, x, cfg, pos)
    else:
        x, _ = jax.lax.scan(lambda c, lp: (fn(lp, c, cfg, pos), None),
                            x, params.layers)
    x = rms_norm(x, params.ln_f, cfg.norm_eps)
    head = params.lm_head if params.lm_head is not None else params.embed.T
    return jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.dtype))


def lm_loss(params: LMParams, tokens: jax.Array, cfg: ArchConfig,
            prefix_embed: Optional[jax.Array] = None) -> jax.Array:
    logits = forward(params, tokens, cfg, prefix_embed=prefix_embed)
    if prefix_embed is not None:
        logits = logits[:, prefix_embed.shape[1]:]
    return cross_entropy(logits[:, :-1], tokens[:, 1:])


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

class DecodeState(NamedTuple):
    cache: A.KVCache        # stacked [L, B, S_max, KV, hd]
    pos: jax.Array          # [] next position to write


def init_decode(cfg: ArchConfig, batch: int, s_max: int) -> DecodeState:
    return DecodeState(
        cache=A.KVCache.init(cfg, batch, s_max, layers=cfg.n_layers),
        pos=jnp.int32(0))


def decode_step(params: LMParams, state: DecodeState, token: jax.Array,
                cfg: ArchConfig):
    """One serving step: token [B] -> logits [B, V], updated state."""
    x = params.embed[token][:, None, :].astype(cfg.dtype)   # [B,1,D]

    def body(carry, inp):
        x = carry
        lp, layer_cache = inp
        h = rms_norm(x, lp.ln_attn, cfg.norm_eps)
        a, new_cache = A.attention_decode(lp.attn, h, layer_cache,
                                          state.pos, cfg)
        x = x + a
        h = rms_norm(x, lp.ln_mlp, cfg.norm_eps)
        if cfg.is_moe:
            x = x + M.moe_ffn(lp.moe, h, cfg)
        else:
            x = x + mlp(lp.mlp, h)
        return x, new_cache

    if cfg.unroll_layers:
        caches = []
        for i in range(cfg.n_layers):
            pick = lambda a, i=i: a[i]
            lp = jax.tree_util.tree_map(pick, params.layers)
            lc = jax.tree_util.tree_map(pick, state.cache)
            x, nc = body(x, (lp, lc))
            caches.append(nc)
        new_cache = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *caches)
    else:
        x, new_cache = jax.lax.scan(body, x, (params.layers, state.cache))
    x = rms_norm(x, params.ln_f, cfg.norm_eps)
    head = params.lm_head if params.lm_head is not None else params.embed.T
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.dtype))[:, 0]
    return logits, DecodeState(cache=new_cache, pos=state.pos + 1)


def prefill(params: LMParams, tokens: jax.Array, cfg: ArchConfig,
            s_max: int) -> tuple[jax.Array, DecodeState]:
    """Prefill the KV cache with a full prompt; returns last-token logits.

    Implemented as full-sequence attention with K/V written to the cache —
    one pass, no token loop (this is the `prefill_32k` shape's program).
    """
    b, s = tokens.shape
    x = params.embed[tokens].astype(cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(x, lp):
        h = rms_norm(x, lp.ln_attn, cfg.norm_eps)
        from repro.models.common import apply_rope
        q = jnp.einsum("bsd,dhk->bshk", h, lp.attn.wq)
        k = jnp.einsum("bsd,dhk->bshk", h, lp.attn.wk)
        v = jnp.einsum("bsd,dhk->bshk", h, lp.attn.wv)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        o = A._sdpa_train(q, k, v, causal=True, impl=cfg.attn_impl,
                          chunk=cfg.attn_chunk)
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp.attn.wo)
        h2 = rms_norm(x, lp.ln_mlp, cfg.norm_eps)
        x = x + (M.moe_ffn(lp.moe, h2, cfg) if cfg.is_moe
                 else mlp(lp.mlp, h2))
        pad = s_max - s
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x, A.KVCache(k=ck, v=cv)

    if cfg.unroll_layers:
        caches = []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a, i=i: a[i], params.layers)
            x, lc = body(x, lp)
            caches.append(lc)
        cache = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches)
    else:
        x, cache = jax.lax.scan(body, x, params.layers)
    x = rms_norm(x, params.ln_f, cfg.norm_eps)
    head = params.lm_head if params.lm_head is not None else params.embed.T
    logits = jnp.einsum("bd,dv->bv", x[:, -1], head.astype(cfg.dtype))
    return logits, DecodeState(cache=cache, pos=jnp.int32(s))
