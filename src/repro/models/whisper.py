"""Whisper-medium backbone (arXiv:2212.04356): encoder-decoder transformer.

Per the brief, only the transformer backbone is modelled: the conv/mel
frontend is a stub — ``input_specs()`` supplies precomputed frame embeddings
[B, n_frames, D].  Encoder: bidirectional self-attention + GELU MLP.
Decoder: causal self-attention + cross-attention into the encoder output.
Whisper uses LayerNorm (with bias) and learned positions; MHA (kv == heads).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models.common import (ArchConfig, cross_entropy, dense_init,
                                 embed_init, layer_norm, split_keys)


class FFN(NamedTuple):
    w1: jax.Array
    b1: jax.Array
    w2: jax.Array
    b2: jax.Array


class EncLayer(NamedTuple):
    ln1_s: jax.Array
    ln1_b: jax.Array
    attn: A.AttnParams
    ln2_s: jax.Array
    ln2_b: jax.Array
    ffn: FFN


class DecLayer(NamedTuple):
    ln1_s: jax.Array
    ln1_b: jax.Array
    self_attn: A.AttnParams
    ln2_s: jax.Array
    ln2_b: jax.Array
    cross_attn: A.AttnParams
    ln3_s: jax.Array
    ln3_b: jax.Array
    ffn: FFN


class WhisperParams(NamedTuple):
    enc_pos: jax.Array        # [n_frames, D] (sinusoidal, fixed init)
    enc_layers: EncLayer      # stacked
    enc_lnf_s: jax.Array
    enc_lnf_b: jax.Array
    tok_embed: jax.Array      # [V, D]
    dec_pos: jax.Array        # [max_pos, D] learned
    dec_layers: DecLayer      # stacked
    dec_lnf_s: jax.Array
    dec_lnf_b: jax.Array


def _init_ffn(key, d, f, dt) -> FFN:
    k1, k2 = jax.random.split(key)
    return FFN(w1=dense_init(k1, (d, f), in_axis=0, dtype=dt),
               b1=jnp.zeros((f,), dt),
               w2=dense_init(k2, (f, d), in_axis=0, dtype=dt),
               b2=jnp.zeros((d,), dt))


def _ffn(p: FFN, x):
    return jnp.einsum("bsf,fd->bsd",
                      jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p.w1) + p.b1),
                      p.w2) + p.b2


def _sinusoid(n: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_whisper(key, cfg: ArchConfig, max_pos: int = 4096) -> WhisperParams:
    dt = cfg.dtype
    d = cfg.d_model
    ks = split_keys(key, 5)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        z = lambda: jnp.zeros((d,), dt)
        return EncLayer(ln1_s=jnp.ones((d,), dt), ln1_b=z(),
                        attn=A.init_attn(k1, cfg),
                        ln2_s=jnp.ones((d,), dt), ln2_b=z(),
                        ffn=_init_ffn(k2, d, cfg.d_ff, dt))

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        z = lambda: jnp.zeros((d,), dt)
        return DecLayer(ln1_s=jnp.ones((d,), dt), ln1_b=z(),
                        self_attn=A.init_attn(k1, cfg),
                        ln2_s=jnp.ones((d,), dt), ln2_b=z(),
                        cross_attn=A.init_attn(k2, cfg),
                        ln3_s=jnp.ones((d,), dt), ln3_b=z(),
                        ffn=_init_ffn(k3, d, cfg.d_ff, dt))

    n_enc = cfg.n_enc_layers or cfg.n_layers
    return WhisperParams(
        enc_pos=_sinusoid(cfg.n_frames, d).astype(dt),
        enc_layers=jax.vmap(enc_layer)(jax.random.split(ks[0], n_enc)),
        enc_lnf_s=jnp.ones((d,), dt), enc_lnf_b=jnp.zeros((d,), dt),
        tok_embed=embed_init(ks[1], (cfg.vocab, d), dt),
        dec_pos=embed_init(ks[2], (max_pos, d), dt),
        dec_layers=jax.vmap(dec_layer)(jax.random.split(ks[3],
                                                        cfg.n_layers)),
        dec_lnf_s=jnp.ones((d,), dt), dec_lnf_b=jnp.zeros((d,), dt),
    )


def encode(params: WhisperParams, frames: jax.Array, cfg: ArchConfig):
    """frames: [B, T, D] stubbed frame embeddings -> encoder states."""
    x = frames.astype(cfg.dtype) + params.enc_pos[None]

    def body(x, lp: EncLayer):
        h = layer_norm(x, lp.ln1_s, lp.ln1_b)
        x = x + A.attention_train(lp.attn, h, cfg, causal=False,
                                  use_rope=False)
        h = layer_norm(x, lp.ln2_s, lp.ln2_b)
        x = x + _ffn(lp.ffn, h)
        return x, None

    fn = jax.checkpoint(body)
    if cfg.unroll_layers:
        n = cfg.n_enc_layers or cfg.n_layers
        for i in range(n):
            lp = jax.tree_util.tree_map(lambda a, i=i: a[i],
                                        params.enc_layers)
            x, _ = fn(x, lp)
    else:
        x, _ = jax.lax.scan(fn, x, params.enc_layers)
    return layer_norm(x, params.enc_lnf_s, params.enc_lnf_b)


def decode_train(params: WhisperParams, tokens: jax.Array,
                 enc_out: jax.Array, cfg: ArchConfig):
    b, s = tokens.shape
    x = params.tok_embed[tokens].astype(cfg.dtype) + params.dec_pos[None, :s]

    def body(x, lp: DecLayer):
        h = layer_norm(x, lp.ln1_s, lp.ln1_b)
        x = x + A.attention_train(lp.self_attn, h, cfg, causal=True,
                                  use_rope=False)
        h = layer_norm(x, lp.ln2_s, lp.ln2_b)
        x = x + A.cross_attention(lp.cross_attn, h, enc_out, cfg)
        h = layer_norm(x, lp.ln3_s, lp.ln3_b)
        x = x + _ffn(lp.ffn, h)
        return x, None

    fn = jax.checkpoint(body)
    if cfg.unroll_layers:
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a, i=i: a[i],
                                        params.dec_layers)
            x, _ = fn(x, lp)
    else:
        x, _ = jax.lax.scan(fn, x, params.dec_layers)
    x = layer_norm(x, params.dec_lnf_s, params.dec_lnf_b)
    return jnp.einsum("bsd,vd->bsv", x, params.tok_embed.astype(cfg.dtype))


def loss(params: WhisperParams, frames: jax.Array, tokens: jax.Array,
         cfg: ArchConfig):
    enc = encode(params, frames, cfg)
    logits = decode_train(params, tokens, enc, cfg)
    return cross_entropy(logits[:, :-1], tokens[:, 1:])


class WhisperState(NamedTuple):
    self_cache: A.KVCache     # [L, B, S_max, KV, hd]
    cross_k: jax.Array        # [L, B, T, KV, hd] precomputed
    cross_v: jax.Array
    pos: jax.Array


def init_decode(params: WhisperParams, frames: jax.Array, cfg: ArchConfig,
                s_max: int) -> WhisperState:
    """Encode once, precompute cross K/V (the serving fast path)."""
    enc = encode(params, frames, cfg)

    def cross_kv(lp: DecLayer):
        k = jnp.einsum("btd,dhk->bthk", enc, lp.cross_attn.wk)
        v = jnp.einsum("btd,dhk->bthk", enc, lp.cross_attn.wv)
        return k, v

    ck, cv = jax.vmap(cross_kv)(params.dec_layers)
    b = frames.shape[0]
    return WhisperState(
        self_cache=A.KVCache.init(cfg, b, s_max, layers=cfg.n_layers),
        cross_k=ck, cross_v=cv, pos=jnp.int32(0))


def decode_step(params: WhisperParams, st: WhisperState, token: jax.Array,
                cfg: ArchConfig):
    b = token.shape[0]
    pe = params.dec_pos[jnp.minimum(st.pos, params.dec_pos.shape[0] - 1)]
    x = (params.tok_embed[token] + pe)[:, None, :].astype(cfg.dtype)

    def body(x, inp):
        lp, cache, ck, cv = inp
        h = layer_norm(x, lp.ln1_s, lp.ln1_b)
        o, cache = A.attention_decode(lp.self_attn, h, cache, st.pos, cfg,
                                      use_rope=False)
        x = x + o
        h = layer_norm(x, lp.ln2_s, lp.ln2_b)
        q = jnp.einsum("bsd,dhk->bshk", h, lp.cross_attn.wq)
        qg = A._group_heads(q, ck.shape[2])
        logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, ck,
                            preferred_element_type=jnp.float32) \
            * cfg.hd ** -0.5
        p = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        o = jnp.einsum("bkgqs,bskh->bqkgh", p, cv)
        o = o.reshape(b, 1, cfg.n_heads, cfg.hd)
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp.cross_attn.wo)
        h = layer_norm(x, lp.ln3_s, lp.ln3_b)
        x = x + _ffn(lp.ffn, h)
        return x, cache

    if cfg.unroll_layers:
        caches = []
        for i in range(cfg.n_layers):
            pick = lambda a, i=i: a[i]
            inp = jax.tree_util.tree_map(
                pick, (params.dec_layers, st.self_cache, st.cross_k,
                       st.cross_v))
            x, nc = body(x, inp)
            caches.append(nc)
        new_cache = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *caches)
    else:
        x, new_cache = jax.lax.scan(
            body, x,
            (params.dec_layers, st.self_cache, st.cross_k, st.cross_v))
    x = layer_norm(x[:, 0], params.dec_lnf_s, params.dec_lnf_b)
    logits = jnp.einsum("bd,vd->bv", x, params.tok_embed.astype(cfg.dtype))
    return logits, st._replace(self_cache=new_cache, pos=st.pos + 1)
