"""The observability plane (DESIGN.md §14).

Opt-in recording and analysis over the netsim replay: a
:class:`~repro.obs.recorder.Recorder` attached to the simulator captures
every verb's exact service interval and queue/dependency decomposition
(pure post-hoc observation — recording off is bit-identical to today),
:mod:`repro.obs.export` renders runs as Chrome/Perfetto trace-viewer
JSON plus derived time series, :mod:`repro.obs.forensics` walks the
top-K slowest ops' dependency chains backwards into a four-component
latency attribution, and :mod:`repro.obs.metrics` folds everything into
the ``RunResult.obs`` registry.
"""
from repro.obs.export import timeseries, to_chrome_trace, write_chrome_trace
from repro.obs.forensics import attribute_ops, span_accounting
from repro.obs.metrics import summarize
from repro.obs.recorder import Recorder, Segment

__all__ = ["Recorder", "Segment", "to_chrome_trace", "write_chrome_trace",
           "timeseries", "attribute_ops", "span_accounting", "summarize"]
