"""Render a recorded run as a Chrome/Perfetto trace + derived series.

:func:`to_chrome_trace` emits the Trace Event Format dict that
``chrome://tracing`` and https://ui.perfetto.dev load directly
(``{"traceEvents": [...]}``; timestamps in microseconds):

* one **process per memory server** (pid ``1000+ms``) with two threads —
  the NIC message unit (tid 0) and the atomic unit (tid 1) — carrying a
  complete ("X") event per verb service span, named ``role/KIND``;
* one **process per compute server** (pid ``2000+cs``; 2000 alone when
  the run has a single unattributed frontend) with one thread per lane
  group, carrying each op's arrival→completion span;
* chaos-plane faults as global instant ("i") markers;
* per-MS NIC utilization as counter ("C") tracks.

:func:`timeseries` computes the derived series on their own: per-MS NIC
utilization and queue depth over time buckets, and per-wave lock-chain
occupancy (time LOCK-plane verbs sat gated before their CAS posted).
"""
from __future__ import annotations

import json

import numpy as np

from repro.core import verbs as V
from repro.obs.recorder import PS_PER_S, Recorder

_KIND_NAMES = ("READ", "WRITE", "CAS")
_LANE_TRACKS = 16   # lanes fold onto this many threads per CS process


def _meta(pid: int, name: str, threads: dict[int, str]) -> list[dict]:
    ev = [dict(ph="M", pid=pid, tid=0, name="process_name",
               args=dict(name=name))]
    for tid, tname in threads.items():
        ev.append(dict(ph="M", pid=pid, tid=tid, name="thread_name",
                       args=dict(name=tname)))
    return ev


def to_chrome_trace(rec: Recorder, *, utilization_buckets: int = 64) -> dict:
    """Build the trace-viewer JSON dict for a recorded run."""
    ev: list[dict] = []
    seen_ms: set[int] = set()
    seen_cs: set[int] = set()
    for si, seg in enumerate(rec.segments):
        if not seg.n_verbs:
            continue
        t0 = seg.t0_ps
        seen_ms.update(int(m) for m in np.unique(seg.ms))
        # MS-side device spans
        for i in range(seg.n_verbs):
            k = int(seg.kind[i])
            name = f"{V.ROLE_NAMES[int(seg.role[i])]}/{_KIND_NAMES[k]}"
            args = dict(seg=si, verb=i, lane=int(seg.lane[i]),
                        cs=int(seg.cs[i]), doorbell=int(seg.doorbell[i]),
                        nbytes=int(seg.nbytes[i]),
                        nic_wait_us=int(seg.nic_wait_ps[i]) / 1e6)
            ev.append(dict(ph="X", pid=1000 + int(seg.ms[i]), tid=0,
                           ts=(t0 + int(seg.start_ps[i])) / 1e6,
                           dur=int(seg.svc_ps[i]) / 1e6,
                           name=name, cat=seg.label or "phase", args=args))
            if k == V.CAS:
                ev.append(dict(
                    ph="X", pid=1000 + int(seg.ms[i]), tid=1,
                    ts=(t0 + int(seg.comp_ps[i]) - seg.rtt_ps
                        - seg.cas_ps) / 1e6,
                    dur=seg.cas_ps / 1e6, name=name,
                    cat=seg.label or "phase",
                    args=dict(args, atomic_wait_us=int(
                        seg.atomic_wait_ps[i]) / 1e6)))
        # CS-side op spans (arrival -> completion per lane)
        arr, comp, fin = seg.lane_tables()
        for ln in np.flatnonzero(fin >= 0):
            c = int(seg.cs[int(fin[ln])])
            pid = 2000 + max(c, 0)
            seen_cs.add(max(c, 0))
            ev.append(dict(ph="X", pid=pid, tid=int(ln) % _LANE_TRACKS,
                           ts=(t0 + int(arr[ln])) / 1e6,
                           dur=int(comp[ln] - arr[ln]) / 1e6,
                           name=f"{seg.label or 'op'} lane{int(ln)}",
                           cat="ops", args=dict(seg=si, lane=int(ln))))
    for f in rec.faults:
        ev.append(dict(ph="i", s="g", pid=0, tid=0,
                       ts=f["t_ps"] / 1e6, name=f"fault:{f['kind']}",
                       cat="chaos",
                       args={k: v for k, v in f.items() if k != "t_ps"}))
    ts = timeseries(rec, buckets=utilization_buckets)
    for m in sorted(seen_ms):
        for t, u in zip(ts["t_s"], ts["nic_util"][m]):
            ev.append(dict(ph="C", pid=1000 + m, tid=0,
                           ts=t * 1e6, name="nic_util",
                           args=dict(util=round(float(u), 4))))
    head = _meta(0, "chaos", {0: "faults"}) if rec.faults else []
    for m in sorted(seen_ms):
        head += _meta(1000 + m, f"MS{m}",
                      {0: "nic msg unit", 1: "atomic unit"})
    for c in sorted(seen_cs):
        head += _meta(2000 + c, f"CS{c}",
                      {t: f"lanes %{_LANE_TRACKS}=={t}"
                       for t in range(_LANE_TRACKS)})
    return dict(traceEvents=head + ev, displayTimeUnit="ms")


def write_chrome_trace(rec: Recorder, path: str, **kw) -> str:
    """Serialize :func:`to_chrome_trace` to ``path``; returns the path."""
    with open(path, "w") as f:
        json.dump(to_chrome_trace(rec, **kw), f)
        f.write("\n")
    return path


def timeseries(rec: Recorder, buckets: int = 64) -> dict:
    """Derived time series over the recorded horizon.

    * ``nic_util[ms]``   — fraction of each time bucket the MS's NIC
      message unit was in service;
    * ``queue_depth[ms]`` — mean number of verbs released-but-unserved
      (waiting for the NIC unit) over each bucket;
    * ``lock_chain`` — per segment (wave): total time LOCK-role verbs
      sat gated between release and service (``ready - at`` summed, s) —
      the GLT/LLT chain occupancy of that wave — plus the wave's label
      and chained-verb count.
    """
    n_ms, hi = 0, 0
    for seg in rec.segments:
        if seg.n_verbs:
            n_ms = max(n_ms, int(seg.ms.max()) + 1)
            hi = max(hi, seg.t0_ps + seg.makespan_ps)
    if not n_ms or not hi:
        return dict(t_s=[], nic_util=[], queue_depth=[], lock_chain=[])
    edges = np.linspace(0, hi, buckets + 1).astype(np.int64)
    width = np.diff(edges).astype(np.float64)
    util = np.zeros((n_ms, buckets))
    depth = np.zeros((n_ms, buckets))
    lock_rows = []
    for si, seg in enumerate(rec.segments):
        if not seg.n_verbs:
            continue
        t0 = seg.t0_ps
        for m in np.unique(seg.ms):
            sel = seg.ms == m
            # busy overlap of each service span with each bucket
            lo = t0 + seg.start_ps[sel]
            hi_v = lo + seg.svc_ps[sel]
            ov = (np.minimum(hi_v[:, None], edges[None, 1:])
                  - np.maximum(lo[:, None], edges[None, :-1]))
            util[m] += np.maximum(ov, 0).sum(0) / width
            # waiting overlap: released but not yet in service
            lo = t0 + seg.ready_ps[sel]
            hi_v = t0 + seg.start_ps[sel]
            ov = (np.minimum(hi_v[:, None], edges[None, 1:])
                  - np.maximum(lo[:, None], edges[None, :-1]))
            depth[m] += np.maximum(ov, 0).sum(0) / width
        lk = seg.role == V.LOCK
        gated = lk & (seg.ready_ps > seg.at_ps)
        lock_rows.append(dict(
            segment=si, label=seg.label,
            lock_verbs=int(lk.sum()), chained=int(gated.sum()),
            chain_wait_s=float((seg.ready_ps[lk]
                                - seg.at_ps[lk]).sum() / PS_PER_S)))
    mid = (edges[:-1] + width / 2) / PS_PER_S
    return dict(t_s=mid.tolist(), nic_util=util.tolist(),
                queue_depth=depth.tolist(), lock_chain=lock_rows)
