"""Tail forensics: why is p99 what it is?

:func:`attribute_ops` decomposes every recorded op's end-to-end latency
into exactly four components by walking its dependency/doorbell/GLT-chain
edges backwards from the final verb:

* **nic_queue**  — waiting for a target MS's NIC message unit;
* **atomic_ser** — CAS serialization on an MS's atomic unit;
* **lock_wait**  — lock-*protocol* time the op sat behind: the full
  occupancy (queue + service + RTT) of crossed lock-plane verbs —
  other lanes' CAS / SPIN / UNLOCK hops ahead in the chain — plus any
  spin-retry ``at``-floor staggering of the op's own verbs;
* **service**    — NIC service + CAS execution + RTTs of the op's own
  verbs and of crossed *data* verbs (a predecessor's write-back the
  handover gated on) along the critical chain.

The walk is exact on the int64 ps grid and follows the op's true
critical path *across lane boundaries*.  Each verb's interval
``[ready, comp]`` splits as ``nic_wait + atomic_wait + svc [+ cas] +
rtt``; the verb's binding gate (the dependency whose completion equals
its ready tick) is walked into next — whether it is an earlier verb of
the same lane or another lane's verb (an HOCL handover or cross-CS
GLT-chain edge), because the handover edge itself is instantaneous
(``comp[gate] == ready``): what the waiter physically waits on is the
*predecessor's* verbs moving through the network.  Crossed verbs sort
by what they are: lock-plane verbs (``obj >= 0`` — the per-handover
CAS+UNLOCK round trips the flat rungs pay and HOCL elides) charge to
lock_wait whole; data verbs (the predecessor's write-back, which still
gates a handover after HOCL) decompose into the ordinary NIC-queue /
atomic / service buckets.  Components are clipped to the window after
the op's own arrival, so the identity below survives the crossing; a
walk that terminates on a verb's own ``at`` floor (spin staggering)
charges the remainder to lock_wait.  By construction

    ``nic_queue + atomic_ser + lock_wait + service == comp - arrival``

with integer equality — tests/test_obs.py asserts it verb-for-verb and
ci.sh gates it through ``BENCH_obs.json``.

:func:`span_accounting` is the conservation side: per-MS recorded busy
spans must be non-overlapping per FIFO (the devices are FIFOs — two
verbs cannot be in service at once) and sum to the simulator's busy
time, and every verb's span decomposition must reconcile with its
completion tick.
"""
from __future__ import annotations

import numpy as np

from repro.core import verbs as V
from repro.obs.recorder import PS_PER_S, Recorder, Segment


def _attribute_lane(seg: Segment, fin: int, arrival: int):
    """Walk one op's critical path backwards (crossing lane boundaries
    along binding gate edges); returns the four components in ps
    (exact: they sum to ``comp[fin] - arrival``).

    Every component interval is clipped to ``[arrival, comp[fin]]`` —
    crossed predecessor verbs may predate the op's arrival, and only
    the post-arrival part of their occupancy explains *this* op's
    latency.  The walk stops when the path reaches the arrival tick or
    terminates on an ``at`` floor (the pre-arrival remainder of which
    is charged to lock_wait)."""
    dep, dep2, comp = seg.dep, seg.dep2, seg.comp_ps
    nic = atomic = lock = service = 0
    i = int(fin)
    op_lane = int(seg.lane[fin])

    def clip(lo: int, hi: int) -> int:
        return max(0, hi - max(lo, arrival))

    while True:
        r = int(seg.ready_ps[i])
        s = int(seg.start_ps[i])
        svc_end = s + int(seg.svc_ps[i])
        c = int(comp[i])
        if seg.lane[i] != op_lane and seg.obj[i] >= 0:
            # a crossed lock-plane verb (CAS / SPIN / UNLOCK of another
            # lane): its whole occupancy is lock-protocol overhead this
            # op sat behind — the per-handover CAS+UNLOCK RTTs the flat
            # rungs pay and HOCL elides
            lock += clip(r, c)
        else:
            nic += clip(r, s)
            atomic += clip(svc_end, svc_end + int(seg.atomic_wait_ps[i]))
            # NIC service + (CAS exec, when present) + RTT tail
            service += clip(s, svc_end) + clip(
                svc_end + int(seg.atomic_wait_ps[i]), c)
        if r <= arrival:
            break
        nxt = -1
        for d in (int(dep[i]), int(dep2[i])):
            if d >= 0 and comp[d] == r:
                nxt = d
                break
        if nxt < 0:
            # ready is the verb's own ``at`` floor: spin staggering
            lock += r - arrival
            break
        i = nxt
    return nic, atomic, lock, service


def attribute_ops(rec: Recorder, top_k: int = 0) -> list[dict]:
    """Per-op latency attribution rows, sorted slowest-first.

    One row per recorded (segment, lane) op: identity (segment index,
    phase label, lane, CS), absolute placement (arrival/completion in
    seconds), end-to-end latency, and the four components.  ``top_k``
    truncates to the K slowest ops after sorting (0 = all).
    """
    rows = []
    for si, seg in enumerate(rec.segments):
        arr, comp, fin = seg.lane_tables()
        for ln in np.flatnonzero(fin >= 0):
            f = int(fin[ln])
            a = int(arr[ln])
            nic, atomic, lock, service = _attribute_lane(seg, f, a)
            lat = int(comp[ln]) - a
            rows.append(dict(
                segment=si, label=seg.label, lane=int(ln),
                cs=int(seg.cs[f]),
                arrival_s=(seg.t0_ps + a) / PS_PER_S,
                comp_s=(seg.t0_ps + int(comp[ln])) / PS_PER_S,
                latency_us=lat / 1e6,
                nic_queue_us=nic / 1e6, atomic_ser_us=atomic / 1e6,
                lock_wait_us=lock / 1e6, service_us=service / 1e6,
                residual_ps=lat - (nic + atomic + lock + service)))
    rows.sort(key=lambda r: -r["latency_us"])
    return rows[:top_k] if top_k else rows


def attribution_totals(rows: list[dict]) -> dict:
    """Fold attribution rows into component totals + fractions."""
    tot = dict(nic_queue_s=0.0, atomic_ser_s=0.0, lock_wait_s=0.0,
               service_s=0.0)
    lat = 0.0
    for r in rows:
        tot["nic_queue_s"] += r["nic_queue_us"] * 1e-6
        tot["atomic_ser_s"] += r["atomic_ser_us"] * 1e-6
        tot["lock_wait_s"] += r["lock_wait_us"] * 1e-6
        tot["service_s"] += r["service_us"] * 1e-6
        lat += r["latency_us"] * 1e-6
    tot["latency_s"] = lat
    for k in ("nic_queue", "atomic_ser", "lock_wait", "service"):
        tot[k + "_frac"] = tot[k + "_s"] / lat if lat else 0.0
    tot["ops"] = len(rows)
    return tot


def span_accounting(rec: Recorder) -> dict:
    """Reconcile recorded spans with the simulator (DESIGN.md §14).

    Checks, per segment:

    * per-MS NIC spans ``[start, start+svc]`` are non-overlapping
      (FIFO), per-MS atomic spans ``[comp-rtt-cas, comp-rtt]`` likewise;
    * per-verb reconciliation ``comp - ready == nic_wait + svc
      [+ atomic_wait + cas] + rtt`` holds with integer equality;
    * no span extends past the segment's makespan.

    Returns per-MS busy totals (summed across segments) plus an ``ok``
    verdict; the busy totals are the utilization numerators the exporter
    and metrics registry reuse.
    """
    n_ms = 0
    for seg in rec.segments:
        if seg.n_verbs:
            n_ms = max(n_ms, int(seg.ms.max()) + 1)
    nic_busy = np.zeros(n_ms, np.int64)
    atomic_busy = np.zeros(n_ms, np.int64)
    ok = True
    horizon = 0
    for seg in rec.segments:
        if not seg.n_verbs:
            continue
        cm = seg.kind == V.CAS
        recon = (seg.comp_ps - seg.ready_ps
                 - seg.nic_wait_ps - seg.svc_ps - seg.rtt_ps
                 - np.where(cm, seg.atomic_wait_ps + seg.cas_ps, 0))
        ok &= bool((recon == 0).all())
        mk = seg.makespan_ps
        ok &= bool((seg.start_ps + seg.svc_ps <= mk).all())
        horizon = max(horizon, seg.t0_ps + mk)
        np.add.at(nic_busy, seg.ms, seg.svc_ps)
        if cm.any():
            np.add.at(atomic_busy, seg.ms[cm],
                      np.full(int(cm.sum()), seg.cas_ps, np.int64))
        # FIFO non-overlap per MS (NIC unit, then atomic unit)
        for msk, lo, hi in (
                (np.ones(seg.n_verbs, bool), seg.start_ps,
                 seg.start_ps + seg.svc_ps),
                (cm, seg.comp_ps - seg.rtt_ps - seg.cas_ps,
                 seg.comp_ps - seg.rtt_ps)):
            idx = np.flatnonzero(msk)
            if not idx.size:
                continue
            o = np.lexsort((lo[idx], seg.ms[idx]))
            idx = idx[o]
            same = seg.ms[idx][1:] == seg.ms[idx][:-1]
            ok &= bool((hi[idx][:-1][same] <= lo[idx][1:][same]).all())
    return dict(ok=bool(ok), n_ms=n_ms, horizon_s=horizon / PS_PER_S,
                nic_busy_s=(nic_busy / PS_PER_S).tolist(),
                atomic_busy_s=(atomic_busy / PS_PER_S).tolist())
