"""The metrics registry: fold a recorded run into ``RunResult.obs``.

One json-safe dict per recorded run — aggregate latency attribution
(all ops and the top-K tail), per-MS busy/utilization totals, the span
conservation verdict, and the top-K forensics table itself.  This is
the shape ``BENCH_obs.json`` serializes and ci.sh gates.
"""
from __future__ import annotations

import numpy as np

from repro.obs.forensics import (attribute_ops, attribution_totals,
                                 span_accounting)
from repro.obs.recorder import Recorder


def summarize(rec: Recorder, tail_k: int = 16) -> dict:
    """Aggregate a recorder's captures (empty dict when nothing was
    recorded, so unrecorded runs serialize unchanged)."""
    if not rec.segments:
        return {}
    rows = attribute_ops(rec)
    spans = span_accounting(rec)
    tail = rows[:tail_k]
    horizon = spans["horizon_s"]
    util = [b / horizon if horizon else 0.0 for b in spans["nic_busy_s"]]
    lat = np.array([r["latency_us"] for r in rows])
    return dict(
        segments=rec.n_segments, verbs=rec.n_verbs, ops=len(rows),
        faults=len(rec.faults), tail_k=int(tail_k),
        attribution=attribution_totals(rows),
        tail_attribution=attribution_totals(tail),
        tail=tail,
        attr_residual_ps=int(max((abs(r["residual_ps"]) for r in rows),
                                 default=0)),
        p99_latency_us=float(np.percentile(lat, 99)) if lat.size else 0.0,
        horizon_s=horizon,
        nic_util=util,
        nic_busy_s=spans["nic_busy_s"],
        atomic_busy_s=spans["atomic_busy_s"],
        spans_ok=spans["ok"])
