"""Recorder — capture the netsim replay's per-verb timing, exactly.

The replay engines (:func:`repro.core.netsim.simulate` /
:func:`simulate_ref`) already compute every verb's NIC service start,
queueing wait and completion tick on the shared int64 picosecond grid,
then fold them into scalar totals.  A :class:`Recorder` attached to a
replay keeps them: at the end of the replay the engine hands the
recorder the ``(trace, comp, wait, start)`` it is about to fold, and
:meth:`Recorder.capture` reconstructs the full per-verb decomposition

    ``ready   = max(at, comp[dep], comp[dep2])``   (the release tick)
    ``nic_wait    = start - ready``                (NIC message-unit queue)
    ``atomic_wait = (comp - rtt - cas) - (start + svc)``  (CAS only)
    ``comp - ready = nic_wait + atomic_wait + svc [+ cas] + rtt``

from the same grid constants the engine used (``_grid_times`` is
deterministic).  Capture is a pure *observation* — it runs after the
replay's last ordering decision and mutates nothing the engine reads —
so recording off (or on) is bit-identical to an unrecorded run; the
neutrality property test in tests/test_obs.py pins this.

Timeline placement: closed-loop phases each start their own relative
timeline at t=0 and the caller accumulates makespans into
``counters["sim_time_s"]``.  Callers therefore :meth:`sync_cursor` to
that counter *before* pricing a phase, and the captured segment is
placed at the cursor — segments tile the accumulated timeline exactly
(and follow chaos-plane time jumps, which move the counter).  Open-loop
replays on a carried :class:`~repro.core.netsim.ServerClock` are already
absolute, so clocked segments sit at t0=0 untranslated.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import netsim
from repro.core import verbs as V

PS_PER_S = netsim.PS_PER_S


@dataclasses.dataclass
class Segment:
    """One captured replay (one phase / wave), per-verb on the ps grid."""

    label: str            # phase kind the caller set ("write", "read", ...)
    clocked: bool         # replayed on a carried absolute ServerClock
    t0_ps: int            # timeline offset (0 when clocked — already absolute)
    cas_ps: int           # atomic-unit service tick count this replay used
    rtt_ps: int
    n_lanes: int
    # per-verb structure (copied views of the trace)
    kind: np.ndarray      # [V] int8  READ/WRITE/CAS
    role: np.ndarray      # [V] int8  verb taxonomy (V.ROLE_NAMES)
    ms: np.ndarray        # [V] target memory server
    lane: np.ndarray      # [V] op lane (-1 = background)
    cs: np.ndarray        # [V] source compute server (-1 = unattributed)
    doorbell: np.ndarray  # [V] doorbell group id
    dep: np.ndarray       # [V] completion gates (-1 = none)
    dep2: np.ndarray
    nbytes: np.ndarray
    obj: np.ndarray       # [V] GLT lock row (-1 = not a lock-plane verb)
    # per-verb timing (int64 ps, segment-relative)
    at_ps: np.ndarray     # earliest-post floor
    ready_ps: np.ndarray  # release tick: max(at, gate completions)
    start_ps: np.ndarray  # NIC service start
    svc_ps: np.ndarray    # NIC service ticks
    comp_ps: np.ndarray   # client-observed completion
    nic_wait_ps: np.ndarray     # queueing for the NIC message unit
    atomic_wait_ps: np.ndarray  # queueing for the atomic unit (CAS only)

    @property
    def n_verbs(self) -> int:
        return int(self.kind.size)

    @property
    def makespan_ps(self) -> int:
        return int(self.comp_ps.max(initial=0))

    def lane_tables(self):
        """Per-lane (arrival, completion, final-verb index) — the op view.

        Arrival is the lane's earliest ``at`` floor (its release time in
        open loop, the phase start in closed loop); completion is the
        lane's last verb completion (``latency_s`` in ``_finish_sim``);
        the final verb is the latest-completing verb (max index on ties,
        matching the FIFO's deterministic order).  Lanes with no verbs
        report final = -1.
        """
        lm = self.lane >= 0
        arr = np.full(self.n_lanes, np.iinfo(np.int64).max, np.int64)
        comp = np.zeros(self.n_lanes, np.int64)
        fin = np.full(self.n_lanes, -1, np.int64)
        if self.n_lanes and lm.any():
            np.minimum.at(arr, self.lane[lm], self.at_ps[lm])
            np.maximum.at(comp, self.lane[lm], self.comp_ps[lm])
            lane_c = np.where(lm, self.lane, 0)
            cand = lm & (self.comp_ps == comp[lane_c])
            fin[self.lane[cand]] = np.flatnonzero(cand)  # later index wins
        arr[fin < 0] = 0
        return arr, comp, fin


class Recorder:
    """Collects :class:`Segment` captures plus chaos fault markers."""

    def __init__(self):
        self.segments: list[Segment] = []
        self.faults: list[dict] = []
        self.phase: str = ""
        self.cursor_ps: int = 0

    # -- caller-side placement helpers ---------------------------------
    def set_phase(self, label: str) -> None:
        """Label the next capture(s) (e.g. "write", "read", "maint")."""
        self.phase = str(label)

    def sync_cursor(self, t_s: float) -> None:
        """Place the next *unclocked* capture at absolute ``t_s`` —
        callers pass their accumulated ``counters["sim_time_s"]`` before
        pricing a closed-loop phase, so relative phase timelines tile
        the run's accumulated timeline (chaos time jumps included)."""
        self.cursor_ps = int(round(float(t_s) * PS_PER_S))

    def mark_fault(self, kind: str, t_s: float, **detail) -> None:
        """Record a chaos-plane fault event (an instant marker in the
        exported timeline)."""
        self.faults.append(dict(kind=str(kind),
                                t_ps=int(round(float(t_s) * PS_PER_S)),
                                **detail))

    # -- the capture hook (called by the replay engines) ----------------
    def capture(self, trace: V.VerbTrace, net, onchip: bool,
                comp_ps: np.ndarray, wait_ps: np.ndarray,
                start_ps: np.ndarray, *, clocked: bool) -> None:
        n = trace.n_verbs
        if n == 0:
            return
        svc, cas_ps, rtt_ps, at_ps = netsim._grid_times(trace, net, onchip)
        dep, dep2 = trace.dep, trace.dep2
        ready = at_ps.copy()
        for col in (dep, dep2):
            m = col >= 0
            if m.any():
                ready[m] = np.maximum(ready[m], comp_ps[col[m]])
        nic_wait = start_ps - ready
        atomic_wait = np.zeros(n, np.int64)
        cm = trace.kind == V.CAS
        if cm.any():
            # CAS: comp = atomic_start + cas + rtt; it queued for the
            # atomic unit from its NIC service end (start + svc)
            atomic_wait[cm] = (comp_ps[cm] - rtt_ps - cas_ps
                               - (start_ps[cm] + svc[cm]))
        lane_cs = trace.meta.get("lane_cs") if trace.meta else None
        if lane_cs is not None and len(lane_cs):
            lane_c = np.where(trace.lane >= 0, trace.lane, 0)
            cs = np.where(trace.lane >= 0,
                          np.asarray(lane_cs, np.int64)[lane_c], -1)
        else:
            cs = np.full(n, -1, np.int64)
        obj = (trace.obj.astype(np.int64) if trace.obj is not None
               else np.full(n, -1, np.int64))
        self.segments.append(Segment(
            label=self.phase, clocked=bool(clocked),
            t0_ps=0 if clocked else self.cursor_ps,
            cas_ps=cas_ps, rtt_ps=rtt_ps, n_lanes=trace.n_lanes,
            kind=np.array(trace.kind), role=np.array(trace.role),
            ms=np.array(trace.ms, np.int64),
            lane=np.array(trace.lane, np.int64), cs=cs,
            doorbell=np.array(trace.doorbell, np.int64),
            dep=np.array(dep, np.int64), dep2=np.array(dep2, np.int64),
            nbytes=np.array(trace.nbytes, np.int64), obj=obj,
            at_ps=at_ps, ready_ps=ready, start_ps=np.array(start_ps),
            svc_ps=svc, comp_ps=np.array(comp_ps),
            nic_wait_ps=nic_wait, atomic_wait_ps=atomic_wait))

    # -- totals ---------------------------------------------------------
    @property
    def n_verbs(self) -> int:
        return sum(s.n_verbs for s in self.segments)

    @property
    def n_segments(self) -> int:
        return len(self.segments)
