"""Gradient compression for cross-pod all-reduce.

``int8``: per-tensor symmetric quantization with an fp32 scale before the
(XLA-inserted) gradient all-reduce, dequantized immediately after.  Because
jit sees q->dq as the data crossing the replica boundary, the collective
moves ~4x fewer bytes over the slow pod interconnect — visible as reduced
all-reduce bytes in the dry-run HLO (EXPERIMENTS.md §Perf).  Error feedback
is left to the optimizer's momentum (standard practice for 1-step EF).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _int8_qdq(g: jax.Array) -> jax.Array:
    if g.dtype == jnp.int32 or g.ndim == 0:
        return g
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
    q = q.astype(jnp.int8)
    return (q.astype(jnp.float32) * scale).astype(g.dtype)


def compress_grads(grads, method: str):
    if method == "none":
        return grads
    if method == "int8":
        return jax.tree_util.tree_map(_int8_qdq, grads)
    raise ValueError(f"unknown compression: {method}")
