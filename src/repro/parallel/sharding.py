"""Sharding rules: DP / TP / EP / SP over the production mesh.

Mesh axes: ``pod`` (inter-pod DP), ``data`` (DP / FSDP / SP), ``model``
(TP / EP / the index's "mem" axis).  JAX requires sharded dims to divide the
axis size, so every rule is a *preference list* — the first candidate dim
divisible by the axis size wins, otherwise the tensor falls back to the next
scheme (e.g. 40 q-heads can't split 16-way, so attention falls back from
head-parallel (Megatron column) to d_model-parallel (row) with a psum):

* attention  wq/wk/wv: heads → d_model → head_dim;  wo: heads → d_model
* MLP        gate/up: d_ff → d_model;  down: d_ff → d_model
* MoE        experts (EP) → per-expert d_ff (TP-in-expert)
* embeddings vocab → d_model
* KV cache   batch over data; head_dim over model (fits 32k caches)

Rules are name-driven over the parameter pytree (NamedTuples/dicts), so the
same function covers every architecture family.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MODEL = "model"
DATA = "data"
POD = "pod"


def dp_axes(mesh: Mesh):
    """Batch/data-parallel axes (includes pod when present)."""
    return (POD, DATA) if POD in mesh.axis_names else (DATA,)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def name_tree(tree: Any, prefix: str = "") -> Any:
    """Same-structure tree of dotted field names (NamedTuple/dict aware)."""
    if tree is None:
        return None
    if hasattr(tree, "_fields"):
        vals = [name_tree(getattr(tree, f), f"{prefix}{f}.")
                for f in tree._fields]
        return type(tree)(*vals)
    if isinstance(tree, dict):
        return {k: name_tree(v, f"{prefix}{k}.") for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(name_tree(v, f"{prefix}{i}.")
                          for i, v in enumerate(tree))
    return prefix.rstrip(".")


def _pick(shape: Sequence[int], prefs: Sequence[int], size: int,
          axis: str = MODEL) -> P:
    """First preferred dim (negative index) divisible by ``size`` wins."""
    spec: list = [None] * len(shape)
    for d in prefs:
        if len(shape) >= -d and shape[d] % size == 0 and shape[d] >= size:
            spec[d] = axis
            return P(*spec)
    return P(*spec)


def param_spec(name: str, shape: Sequence[int], mesh: Mesh) -> P:
    """TP/EP PartitionSpec for one named parameter."""
    m = _axis_size(mesh, MODEL)
    n = name.split(".")[-1]
    holder = name.split(".")[-2] if "." in name else ""

    if len(shape) == 0:
        return P()
    # --- norms / scalars / biases on d_model ---
    if n.startswith(("ln", "norm")) or n in ("b_a", "b_i", "conv_b", "b2",
                                             "lam", "mu_x", "mu_ck",
                                             "mu_cr", "w0", "mu"):
        return P(*([None] * len(shape)))
    # --- embeddings / heads ---
    if n in ("embed", "tok_embed"):
        return _pick(shape, (-2, -1), m)           # vocab, else d_model
    if n in ("head", "lm_head"):
        return _pick(shape, (-1, -2), m)           # vocab, else d_model
    if n in ("dec_pos", "enc_pos"):
        return _pick(shape, (-2,), m)
    # --- attention ---
    if n in ("wq", "wk", "wv") and holder in ("attn", "self_attn",
                                              "cross_attn", ""):
        return _pick(shape, (-2, -3, -1), m)       # heads, d_model, hd
    if n == "wo" and holder in ("attn", "self_attn", "cross_attn", ""):
        return _pick(shape, (-3, -1), m)           # heads, else d_model out
    # --- MoE (4D expert-stacked) / dense MLP ---
    if n in ("w_gate", "w_up"):
        if len(shape) >= 4 or holder == "moe":
            return _pick(shape, (-3, -1, -2), m)   # E, F, D
        return _pick(shape, (-1, -2), m)           # F, else D
    if n == "w_down":
        if len(shape) >= 4 or holder == "moe":
            return _pick(shape, (-3, -2, -1), m)   # E, F, D
        return _pick(shape, (-2, -1), m)
    if n == "router":
        return P(*([None] * len(shape)))
    if n in ("shared_gate", "shared_up"):
        return _pick(shape, (-1, -2), m)
    if n == "shared_down":
        return _pick(shape, (-2, -1), m)
    # --- whisper FFN ---
    if n == "w1":
        return _pick(shape, (-1, -2), m)
    if n == "w2":
        return _pick(shape, (-2, -1), m)
    if n == "b1":
        return _pick(shape, (-1,), m)
    # --- rwkv ---
    if n in ("wr", "wk", "wv", "wg", "wck", "wcr", "lora_a", "w_a"):
        return _pick(shape, (-1,), m)          # column-parallel (heads)
    if n in ("wo", "wcv"):
        # row-parallel pair of the column-parallel projections above: one
        # psum per mix block instead of per-projection [B,S,D] all-gathers
        return _pick(shape, (-2, -1), m)
    if n in ("w_b", "lora_b"):
        return _pick(shape, (-1, -2), m)
    if n == "u":
        return _pick(shape, (-2,), m)
    # --- rg-lru ---
    if n in ("w_x", "w_y"):
        return _pick(shape, (-1, -2), m)
    if n == "conv_w":
        return _pick(shape, (-1,), m)
    if n == "w_i":
        return _pick(shape, (-1,), m)
    if n == "w_o":
        return _pick(shape, (-2, -1), m)
    # --- fallback: last dim if divisible ---
    return _pick(shape, (-1, -2), m)


def params_pspecs(params: Any, mesh: Mesh) -> Any:
    names = name_tree(params)
    return jax.tree_util.tree_map(
        lambda nm, p: param_spec(nm, np.shape(p), mesh), names, params)


def params_shardings(params: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  params_pspecs(params, mesh))


# --------------------------------------------------------------------------
# activations / batches / decode state
# --------------------------------------------------------------------------

def batch_pspecs(batch: dict, mesh: Mesh) -> dict:
    """tokens [B,S] + stub embeddings sharded over the DP axes."""
    dp = dp_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in dp]))

    def spec(x):
        shape = np.shape(x)
        if shape and shape[0] % dsize == 0 and shape[0] >= dsize:
            return P(dp, *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))

    return {k: spec(v) for k, v in batch.items()}


def state_spec(name: str, shape: Sequence[int], mesh: Mesh) -> P:
    """Decode-state sharding: batch over data, sequence over model.

    KV caches ([L,B,S,KV,hd]) shard the *sequence* dim over model —
    attention then reduces only softmax statistics and a tiny partial
    output across shards (sequence-parallel decode).  Sharding hd instead
    makes GSPMD all-gather the whole cache per layer ("involuntary full
    rematerialization") — measured in EXPERIMENTS.md §Perf.  Recurrent
    states ([L,B,H,N,N], [L,B,W,R], [L,B,R]) shard their widest inner dim.
    """
    d = _axis_size(mesh, DATA)
    m = _axis_size(mesh, MODEL)
    spec: list = [None] * len(shape)
    if len(shape) == 0:
        return P()
    # find a batch-like dim: the first dim (or second when stacked by layer)
    for bdim in (1, 0):
        if len(shape) > bdim and shape[bdim] % d == 0 and shape[bdim] >= d:
            spec[bdim] = DATA
            break
    # model axis: sequence dim (index 2) of stacked caches first, then the
    # innermost dims
    cands = (2, -1, -2) if len(shape) >= 4 else (-1, -2)
    for mdim in cands:
        i = mdim if mdim >= 0 else len(shape) + mdim
        if 0 <= i < len(shape) and shape[i] % m == 0 and shape[i] >= m \
                and spec[i] is None:
            spec[i] = MODEL
            break
    return P(*spec)


def decode_state_pspecs(state: Any, mesh: Mesh) -> Any:
    names = name_tree(state)
    return jax.tree_util.tree_map(
        lambda nm, x: state_spec(nm, np.shape(x), mesh), names, state)


def describe(params: Any, mesh: Mesh, max_rows: int = 0) -> str:
    """Human-readable sharding table (README/EXPERIMENTS material)."""
    names = jax.tree_util.tree_leaves(name_tree(params))
    leaves = jax.tree_util.tree_leaves(params)
    specs = jax.tree_util.tree_leaves(
        params_pspecs(params, mesh), is_leaf=lambda x: isinstance(x, P))
    rows = []
    for nm, lf, sp in zip(names, leaves, specs):
        rows.append(f"{nm:48s} {str(np.shape(lf)):24s} {sp}")
    if max_rows:
        rows = rows[:max_rows]
    return "\n".join(rows)
