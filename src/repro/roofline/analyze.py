"""Roofline extraction from a compiled dry-run artifact.

Three terms per (arch × shape × mesh), all in seconds (lower bound):

* compute    = HLO_FLOPs(per device) / peak_FLOP/s
* memory     = HLO_bytes(per device) / HBM_bw
* collective = collective_bytes(per device) / ICI link bw

``cost_analysis`` reports the SPMD-partitioned (= per-device) module.
Collective bytes are NOT in cost_analysis, so we parse the optimized HLO and
sum transfer sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (max of operand/result shape — an upper
bound on the per-device transfer).
"""
from __future__ import annotations

import re
from typing import Any

PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s+[a-z0-9]+\[[0-9,]*\][^=]*?\b(" + "|".join(COLLECTIVES)
    + r")(?:-start|-done)?\(")
_TUPLE_OP_RE = re.compile(
    r"=\s+\([^)]*\)[^=]*?\b(" + "|".join(COLLECTIVES)
    + r")(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-op-kind transfer bytes over the (per-device) HLO module."""
    out: dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line) or _TUPLE_OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        if f" {kind}-done(" in line or f"{kind}-done(" in line:
            continue  # count start/done pairs once (the -start carries data)
        sizes = [_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(line)]
        if not sizes:
            continue
        out[kind] += max(sizes)
        counts[kind] += 1
    total = sum(out.values())
    return dict(per_kind=out, counts=counts, total=total)


def roofline(flops: float, bytes_accessed: float, coll_bytes: float,
             peak=PEAK_FLOPS_BF16, hbm=HBM_BW, ici=ICI_BW) -> dict:
    compute_s = flops / peak
    memory_s = bytes_accessed / hbm
    collective_s = coll_bytes / ici
    terms = dict(compute_s=compute_s, memory_s=memory_s,
                 collective_s=collective_s)
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    useful = max(compute_s, 1e-30)
    return dict(**terms, dominant=dominant, bound_s=bound,
                roofline_fraction=useful / bound if bound else 0.0)


def analyze_compiled(compiled: Any) -> dict:
    """Full extraction from a jax compiled object."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):       # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            mem[f] = getattr(ma, f, None)
    except Exception as e:              # pragma: no cover
        mem["error"] = str(e)
    rl = roofline(flops, bytes_accessed, coll["total"])
    return dict(flops=flops, bytes_accessed=bytes_accessed,
                collectives=coll, memory=mem, roofline=rl)


def model_flops(n_params_active: float, tokens: float,
                training: bool) -> float:
    """6ND for training, 2ND for inference forward."""
    return (6.0 if training else 2.0) * n_params_active * tokens
