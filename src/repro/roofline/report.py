"""Generate the EXPERIMENTS.md roofline tables from dry-run JSONs.

    PYTHONPATH=src python -m repro.roofline.report experiments/dryrun
"""
from __future__ import annotations

import glob
import json
import os
import sys

ADVICE = {
    ("memory_s", "train"): "stream attention/logits (chunked), fuse "
                           "residual+norm, bf16 master-cast",
    ("memory_s", "prefill"): "chunked attention + KV-write fusion",
    ("memory_s", "decode"): "KV-cache layout/quantization; batch more "
                            "sequences per chip",
    ("collective_s", "train"): "EP all-to-all instead of dense EP "
                               "collectives; overlap grad all-reduce",
    ("collective_s", "prefill"): "shard activations on sequence (SP) to "
                                 "shrink TP all-gathers",
    ("collective_s", "decode"): "keep TP partials resident; fuse "
                                "all-reduces across layers",
    ("compute_s", "train"): "near roofline — MXU-align tile shapes",
    ("compute_s", "prefill"): "near roofline — MXU-align tile shapes",
    ("compute_s", "decode"): "near roofline",
}


def load(out_dir: str, mesh: str = "single") -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(out_dir, f"*__{mesh}.json"))):
        d = json.load(open(f))
        d["_file"] = os.path.basename(f)
        rows.append(d)
    return rows


def kind_of(shape: str) -> str:
    return {"train_4k": "train", "prefill_32k": "prefill"}.get(
        shape, "decode")


def roofline_table(rows: list[dict]) -> str:
    out = ["| arch | shape | compute (ms) | memory (ms) | collective (ms) "
           "| dominant | roofline frac | 6ND/HLO | next lever |",
           "|---|---|---|---|---|---|---|---|---|"]
    for d in sorted(rows, key=lambda r: (r.get("arch", ""),
                                         r.get("shape", ""))):
        if d["status"] == "skipped":
            continue
        if d["status"] != "ok":
            out.append(f"| {d.get('arch','?')} | {d.get('shape','?')} | "
                       f"ERROR | | | | | | {d.get('error','')[:60]} |")
            continue
        rl = d["roofline"]
        dom = rl["dominant"]
        advice = ADVICE.get((dom, kind_of(d["shape"])), "")
        out.append(
            f"| {d['arch']} | {d['shape']} | {rl['compute_s']*1e3:.2f} | "
            f"{rl['memory_s']*1e3:.2f} | {rl['collective_s']*1e3:.2f} | "
            f"{dom.replace('_s','')} | {rl['roofline_fraction']*100:.1f}% |"
            f" {d['useful_ratio']:.2f} | {advice} |")
    return "\n".join(out)


def skip_table(rows: list[dict]) -> str:
    out = ["| arch | shape | reason |", "|---|---|---|"]
    for d in rows:
        if d["status"] == "skipped":
            a, s, _ = d["_file"].replace(".json", "").split("__")
            out.append(f"| {a} | {s} | {d['reason']} |")
    return "\n".join(out)


def dryrun_table(rows: list[dict]) -> str:
    out = ["| arch | shape | mesh | compile (s) | args (GB/dev) | "
           "temp (GB/dev) | collectives (#) |",
           "|---|---|---|---|---|---|---|"]
    for d in sorted(rows, key=lambda r: (r.get("arch", ""),
                                         r.get("shape", ""))):
        if d["status"] != "ok":
            continue
        sc = d["scan_compile"]
        mem = sc["memory"]
        args = (mem.get("argument_size_in_bytes") or 0) / 2**30
        temp = (mem.get("temp_size_in_bytes") or 0) / 2**30
        ncoll = sum(sc.get("collective_counts", {}).values())
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
            f"{sc['compile_s']:.0f} | {args:.2f} | {temp:.2f} | {ncoll} |")
    return "\n".join(out)


def main(out_dir="experiments/dryrun"):
    for mesh in ("single", "multi"):
        rows = load(out_dir, mesh)
        if not rows:
            continue
        print(f"\n### Roofline ({mesh}-pod)\n")
        print(roofline_table(rows))
        if mesh == "single":
            print("\n### Skipped cells\n")
            print(skip_table(rows))
            print("\n### Dry-run compile stats\n")
            print(dryrun_table(rows))


if __name__ == "__main__":
    main(*sys.argv[1:])
