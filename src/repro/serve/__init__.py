"""The open-loop serving plane (DESIGN.md §12).

Everything before this package was **closed-loop**: a fixed op count
drained in scheduler rounds, so the reported p99 was a batch artifact.
This package adds the production operating point: arrival-process
generators on netsim's shared picosecond grid (:mod:`.arrivals`), the
per-CS admission/dispatch loop that feeds the cluster's bucketed jitted
waves as arrivals drain (:mod:`.loop`), and the load-sweep driver that
produces latency-vs-offered-load curves, SLO attainment and
max-sustainable-load per system (:mod:`.sweep`).
"""
from repro.serve.arrivals import (ARRIVAL_KINDS, bursty_arrivals,
                                  diurnal_arrivals, make_arrivals,
                                  poisson_arrivals, spliced_arrivals)
from repro.serve.loop import (KIND_ORDER, materialize_ops, run_open_loop,
                              simulate_station, station_trace)
from repro.serve.sweep import load_sweep

__all__ = [
    "ARRIVAL_KINDS", "KIND_ORDER", "bursty_arrivals", "diurnal_arrivals",
    "load_sweep", "make_arrivals", "materialize_ops", "poisson_arrivals",
    "run_open_loop", "simulate_station", "station_trace",
    "spliced_arrivals",
]
