"""Arrival-process generators for the open-loop serving plane.

Every generator emits **absolute arrival timestamps on netsim's shared
integer picosecond grid** (int64 ps, monotone non-decreasing), fully
determined by the seed.  The serving loop thins one global stream
round-robin over the compute servers, so each CS's admission queue stays
sorted and (for Poisson) remains Poisson at ``rate / n_cs``.

Three processes, matching how the load literature characterizes serving
systems (latency-vs-offered-load curves that hockey-stick at
saturation):

* :func:`poisson_arrivals` — homogeneous Poisson: iid exponential
  interarrival gaps, CV = 1.  The M/G/1 analytic tests
  (tests/test_serve_queueing.py) pin the replay against
  Pollaczek–Khinchine with this process.
* :func:`bursty_arrivals` — a 2-state MMPP: a burst state at
  ``burst_factor`` × the mean rate active ``burst_frac`` of the time,
  with exponential state sojourns.  Interarrival CV strictly above
  Poisson's — the property test's definition of "bursty".
* :func:`diurnal_arrivals` — inhomogeneous Poisson under a sinusoidal
  rate envelope (a pocket-sized diurnal trace on simulator time scales),
  generated exactly by thinning.

All three normalize to the requested *mean* rate, so offered load is
comparable across processes.
"""
from __future__ import annotations

import numpy as np

from repro.core.netsim import PS_PER_S
from repro.workloads.spec import ARRIVAL_KINDS  # canonical list lives there

#: int64 ps overflow guard: 2^62 ps ≈ 53 days of simulated time — any
#: realizable run horizon is far below this; hitting it means the rate
#: or count was nonsensical, so fail loudly instead of wrapping.
_MAX_PS = float(np.int64(1) << 62)


def _to_ps(ts_s: np.ndarray) -> np.ndarray:
    """Snap a non-decreasing float timestamp series onto the int64 ps
    grid (monotonicity preserved: rint of a sorted series is sorted)."""
    ts = np.rint(np.asarray(ts_s, np.float64) * PS_PER_S)
    if ts.size and float(ts[-1]) >= _MAX_PS:
        raise OverflowError(
            f"arrival horizon {ts_s[-1]:.3e}s overflows the int64 ps grid")
    return ts.astype(np.int64)


def _check(rate_ops_s: float, n: int) -> None:
    if rate_ops_s <= 0:
        raise ValueError(f"arrival rate must be positive, got {rate_ops_s}")
    if n < 0:
        raise ValueError(f"arrival count must be >= 0, got {n}")


def poisson_arrivals(rate_ops_s: float, n: int, seed: int = 0) -> np.ndarray:
    """Homogeneous Poisson: ``n`` arrivals with iid Exp(1/rate) gaps."""
    _check(rate_ops_s, n)
    if n == 0:
        return np.zeros(0, np.int64)
    rng = np.random.default_rng(seed)
    return _to_ps(np.cumsum(rng.exponential(1.0 / rate_ops_s, size=n)))


def bursty_arrivals(rate_ops_s: float, n: int, seed: int = 0, *,
                    burst_factor: float = 8.0, burst_frac: float = 0.1,
                    burst_ops: float = 64.0) -> np.ndarray:
    """2-state Markov-modulated Poisson process at mean ``rate_ops_s``.

    The burst state runs at ``burst_factor`` × the mean rate and is
    occupied ``burst_frac`` of the time; the low state's rate is set so
    the time-average equals the mean.  State sojourns are exponential —
    a mean burst emits ~``burst_ops`` arrivals.  Within a sojourn the
    conditional arrival times are uniform order statistics (exact for a
    Poisson process observed over a fixed window).
    """
    _check(rate_ops_s, n)
    if not 0.0 < burst_frac < 1.0:
        raise ValueError(f"burst_frac must be in (0,1), got {burst_frac}")
    if burst_factor <= 1.0:
        raise ValueError(f"burst_factor must be > 1, got {burst_factor}")
    if burst_factor * burst_frac >= 1.0:
        raise ValueError(
            f"burst_factor*burst_frac = {burst_factor * burst_frac:g} >= 1 "
            "leaves the low state a negative rate")
    if n == 0:
        return np.zeros(0, np.int64)
    lam_b = burst_factor * rate_ops_s
    lam_l = rate_ops_s * (1.0 - burst_factor * burst_frac) / (1.0 - burst_frac)
    dwell_b = burst_ops / lam_b
    dwell_l = dwell_b * (1.0 - burst_frac) / burst_frac
    rng = np.random.default_rng(seed)
    burst = bool(rng.random() < burst_frac)   # start at stationarity
    t, got, out = 0.0, 0, []
    while got < n:
        lam, dwell_mean = (lam_b, dwell_b) if burst else (lam_l, dwell_l)
        dwell = rng.exponential(dwell_mean)
        k = int(rng.poisson(lam * dwell))
        if k:
            pts = t + np.sort(rng.random(k)) * dwell
            take = pts[:n - got]
            out.append(take)
            got += take.size
        t += dwell
        burst = not burst
    return _to_ps(np.concatenate(out))


def diurnal_arrivals(rate_ops_s: float, n: int, seed: int = 0, *,
                     period_s: float = 5e-3,
                     peak: float = 1.8) -> np.ndarray:
    """Inhomogeneous Poisson with the sinusoidal rate envelope
    ``r(t) = rate * (1 + (peak-1) * sin(2πt/period))`` — mean rate is
    exactly ``rate_ops_s`` and the instantaneous peak/mean ratio is
    ``peak`` (require ``1 < peak <= 2`` so the trough stays
    non-negative).  Generated exactly by thinning a homogeneous Poisson
    stream at the peak rate.
    """
    _check(rate_ops_s, n)
    if not 1.0 < peak <= 2.0:
        raise ValueError(f"diurnal peak must be in (1, 2], got {peak}")
    if period_s <= 0:
        raise ValueError(f"diurnal period must be positive, got {period_s}")
    if n == 0:
        return np.zeros(0, np.int64)
    a = peak - 1.0
    lam_max = rate_ops_s * (1.0 + a)
    rng = np.random.default_rng(seed)
    t, got, out = 0.0, 0, []
    while got < n:
        chunk = max(256, int(1.5 * (n - got) * (1.0 + a)))
        ts = t + np.cumsum(rng.exponential(1.0 / lam_max, size=chunk))
        keep = rng.random(chunk) < \
            (1.0 + a * np.sin(2.0 * np.pi * ts / period_s)) / (1.0 + a)
        pts = ts[keep][:n - got]
        out.append(pts)
        got += pts.size
        t = float(ts[-1])
    return _to_ps(np.concatenate(out))


def make_arrivals(kind: str, rate_ops_s: float, n: int, *, seed: int = 0,
                  burst_factor: float = 8.0, burst_frac: float = 0.1,
                  burst_ops: float = 64.0, diurnal_period_s: float = 5e-3,
                  diurnal_peak: float = 1.8) -> np.ndarray:
    """Dispatch on the spec's ``arrival`` field.  ``"closed"`` stamps
    every op at t=0 — the degenerate open-loop run the differential test
    uses to prove the serving plane reproduces the closed-loop scheduler
    tick-for-tick."""
    if kind == "closed":
        return np.zeros(max(int(n), 0), np.int64)
    if kind == "poisson":
        return poisson_arrivals(rate_ops_s, n, seed)
    if kind == "bursty":
        return bursty_arrivals(rate_ops_s, n, seed,
                               burst_factor=burst_factor,
                               burst_frac=burst_frac, burst_ops=burst_ops)
    if kind == "diurnal":
        return diurnal_arrivals(rate_ops_s, n, seed,
                                period_s=diurnal_period_s, peak=diurnal_peak)
    raise ValueError(f"unknown arrival process {kind!r}; "
                     f"known: {', '.join(ARRIVAL_KINDS)}")


def spliced_arrivals(phases, seed: int = 0, **kw) -> np.ndarray:
    """Concatenate arrival processes back-to-back on one timeline.

    ``phases`` is a sequence of ``(kind, rate_ops_s, n)`` tuples; each
    phase's stream starts where the previous phase's last arrival
    landed, so the splice is a single monotone int64-ps series whose
    rate changes mid-stream — the open-loop face of the chaos plane's
    skew shifts and hot-key storms (a storm is a high-rate phase spliced
    between two nominal ones).  Zero-length phases contribute nothing
    but still hold their position in the per-phase seed derivation, so
    adding or emptying a phase never reseeds its neighbours.  Each phase
    draws from an independent child seed of ``seed``
    (:class:`numpy.random.SeedSequence` spawn-by-index), making the
    whole splice reproducible from ``(phases, seed)`` alone.
    """
    out, t0 = [], np.int64(0)
    for i, (kind, rate, n) in enumerate(phases):
        if int(n) == 0:
            continue
        child = int(np.random.SeedSequence(
            [int(seed), i]).generate_state(1)[0])
        ts = make_arrivals(kind, rate, int(n), seed=child, **kw) + t0
        t0 = ts[-1]
        out.append(ts)
    return np.concatenate(out) if out else np.zeros(0, np.int64)
