"""The open-loop admission/dispatch loop (DESIGN.md §12).

Two layers live here:

* :func:`simulate_station` / :func:`station_trace` — the **single-station
  harness**: one independent verb per op against netsim's per-MS FIFO,
  with arrivals as absolute ``at`` release gates.  This is an M/G/1
  queue by construction (service = ``max(1/iops, bytes/bw)``), which is
  what lets tests/test_serve_queueing.py pin the replay engines against
  the Pollaczek–Khinchine closed forms (M/D/1, M/M/1).

* :func:`run_open_loop` — the **cluster serving loop**: materialize the
  spec's ops in the closed-loop scheduler's exact RNG order
  (:func:`materialize_ops`), thin one global arrival stream round-robin
  into per-CS admission queues, and dispatch waves through the existing
  bucketed jitted phases as arrivals drain.  Arrival timestamps travel
  into the merged traces as release gates and the waves replay on one
  carried :class:`~repro.core.netsim.ServerClock` timeline, so per-op
  sojourn = queueing delay + service, measured — not batch artifacts.

Wave formation is a pure *execution-granularity* knob: because release
gates carry the true arrival times and the clock carries true busy
frontiers, dispatching ops in one wave or many yields identical
completion ticks (the chunking-invariance property the tests pin).  The
host loop therefore batches admissions up to a window of
``batch_fill * n_clients / rate`` seconds purely to keep jit dispatch
count ~O(ops / n_clients).
"""
from __future__ import annotations

import numpy as np

from repro.core import netsim, verbs as V
from repro.core.netsim import PS_PER_S, NetConfig, ServerClock
from repro.serve.arrivals import make_arrivals
from repro.workloads.spec import OP_KINDS, WorkloadSpec

VAL_MASK = (1 << 30) - 1

#: Wave kind execution order — must equal ``run_cluster``'s fixed order
#: (scan, read, rmw, update, delete, insert): the t=0 differential test
#: pins the open loop trace-identical to the closed-loop scheduler.
KIND_ORDER = ("scan", "read", "rmw", "update", "delete", "insert")
KIND_CODE = {k: i for i, k in enumerate(KIND_ORDER)}


# --------------------------------------------------------------------------
# single-station M/G/1 harness
# --------------------------------------------------------------------------

def station_trace(arrival_s: np.ndarray, nbytes: np.ndarray,
                  n_ms: int = 1, start: int = 0) -> V.VerbTrace:
    """One independent READ verb per op (no deps, own doorbell), released
    at its arrival time — netsim's per-MS FIFO then *is* a FIFO queue
    with service ``max(1/iops, bytes/bw)``.  ``start`` is the op's global
    stream position, so MS round-robin assignment is invariant to how
    the stream is chunked into waves."""
    arrival_s = np.asarray(arrival_s, np.float64)
    n = arrival_s.size
    idx = np.arange(n, dtype=np.int64)
    return V.VerbTrace(
        kind=np.full(n, V.READ, np.int8),
        role=np.full(n, V.TRAVERSE, np.int8),
        ms=((idx + int(start)) % max(n_ms, 1)).astype(np.int32),
        nbytes=np.broadcast_to(np.asarray(nbytes, np.int64), (n,)).copy(),
        lane=idx.astype(np.int32), doorbell=idx,
        dep=np.full(n, -1, np.int64), dep2=np.full(n, -1, np.int64),
        at=arrival_s, n_lanes=n)


def simulate_station(arrival_s, nbytes, net: NetConfig | None = None, *,
                     n_ms: int = 1, onchip: bool = True,
                     chunk: int | None = None,
                     engine: str = "wavefront",
                     recorder=None) -> dict:
    """Replay one admission queue against the event simulator.

    ``arrival_s`` must be sorted (an arrival stream); ``nbytes`` is the
    per-op payload (scalar or array) that sets the service time.  With
    ``chunk``, the stream is dispatched in host-side waves of that many
    ops against a carried :class:`ServerClock` — completion ticks are
    identical to the one-shot replay (the chunking-invariance property).

    Returns per-op arrays: ``wait_s`` (queueing delay at the NIC/atomic
    units), ``service_s`` (grid-rounded service time actually charged),
    ``comp_s`` (absolute completion) and ``sojourn_s`` (completion minus
    arrival = wait + service + RTT).
    """
    net = net or NetConfig()
    arrival_s = np.asarray(arrival_s, np.float64)
    n = arrival_s.size
    nbytes = np.broadcast_to(np.asarray(nbytes, np.int64), (n,))
    sim_f = netsim.simulate if engine == "wavefront" else netsim.simulate_ref
    step = n if chunk is None else max(int(chunk), 1)
    # the recorder rides the carried clock, so every chunked wave
    # captures onto one absolute recorded timeline (repro.obs)
    clock = ServerClock.fresh(n_ms)
    clock.recorder = recorder
    waits = np.zeros(n)
    comps = np.zeros(n)
    for lo in range(0, n, step):
        sl = slice(lo, min(lo + step, n))
        tr = station_trace(arrival_s[sl], nbytes[sl], n_ms=n_ms, start=lo)
        sim = sim_f(tr, net, n_ms, onchip, clock=clock)
        waits[sl] = sim["lane_queue_s"]
        comps[sl] = sim["latency_s"]
    svc = np.rint(np.maximum(1.0 / net.nic_iops_small,
                             nbytes / net.nic_bw_Bps) * PS_PER_S) / PS_PER_S
    return dict(wait_s=waits, comp_s=comps, service_s=svc,
                sojourn_s=comps - arrival_s,
                rtt_s=round(net.rtt_s * PS_PER_S) / PS_PER_S)


# --------------------------------------------------------------------------
# cluster op materialization (closed-loop RNG order, replayed up front)
# --------------------------------------------------------------------------

def materialize_ops(spec: WorkloadSpec, streams, n_cs: int, per_cs: int,
                    rounds: int):
    """Pre-draw every op exactly as ``run_cluster`` would.

    The closed-loop scheduler interleaves RNG consumption with
    execution; open-loop admission reorders *execution*, so the draws
    are materialized up front in the scheduler's exact consumption order
    — per round, per kind in :data:`KIND_ORDER`, keys for every CS then
    values for every CS — giving identical per-CS key/value sequences
    and identical shared live-record growth.  ``rmw`` write values are
    *not* drawn (they come from the op's own lookup at execution time,
    as in the closed loop).

    Returns per-CS struct-of-arrays ``(kinds, keys, vals)``: kind codes
    (:data:`KIND_CODE`), int64 keys, int64 values (-1 where derived at
    execution or not applicable).
    """
    kinds = [[] for _ in range(n_cs)]
    keys = [[] for _ in range(n_cs)]
    vals = [[] for _ in range(n_cs)]
    for r in range(rounds):
        counts = [spec.batch_counts(per_cs, salt=r * n_cs + cs)
                  for cs in range(n_cs)]
        for kind in KIND_ORDER:
            if not any(c[kind] for c in counts):
                continue
            draw = streams.draw_insert if kind == "insert" else streams.draw
            ks = [draw(cs, counts[cs][kind]) if counts[cs][kind] else None
                  for cs in range(n_cs)]
            if kind in ("update", "insert"):
                vs = [streams.rngs[cs].integers(0, VAL_MASK, k.size)
                      if k is not None else None
                      for cs, k in enumerate(ks)]
            else:
                vs = [None] * n_cs
            code = KIND_CODE[kind]
            for cs in range(n_cs):
                if ks[cs] is None:
                    continue
                k = np.asarray(ks[cs], np.int64)
                kinds[cs].append(np.full(k.size, code, np.int8))
                keys[cs].append(k)
                vals[cs].append(np.asarray(vs[cs], np.int64)
                                if vs[cs] is not None
                                else np.full(k.size, -1, np.int64))
    cat = lambda ls, dt: (np.concatenate(ls) if ls else np.zeros(0, dt))
    return ([cat(kinds[cs], np.int8) for cs in range(n_cs)],
            [cat(keys[cs], np.int64) for cs in range(n_cs)],
            [cat(vals[cs], np.int64) for cs in range(n_cs)])


# --------------------------------------------------------------------------
# the cluster serving loop
# --------------------------------------------------------------------------

def _execute_wave(cluster, spec, kinds, keys, vals, arr_cs, take) -> None:
    """Dispatch one admitted wave through the cluster's kind waves, in
    the scheduler's fixed kind order, with per-op arrival release
    gates."""
    n_cs = cluster.n_cs
    for kind in KIND_ORDER:
        code = KIND_CODE[kind]
        kby = [None] * n_cs
        vby = [None] * n_cs
        aby = [None] * n_cs
        any_ops = False
        for cs, (lo, hi) in enumerate(take):
            if hi <= lo:
                continue
            m = kinds[cs][lo:hi] == code
            if not m.any():
                continue
            any_ops = True
            kby[cs] = keys[cs][lo:hi][m].astype(np.int32)
            vby[cs] = vals[cs][lo:hi][m].astype(np.int32)
            aby[cs] = arr_cs[cs][lo:hi][m]
        if not any_ops:
            continue
        if kind == "scan":
            cluster.scan_wave(kby, count=spec.scan_len,
                              max_leaves=max(4, spec.scan_len),
                              arrivals_by_cs=aby)
        elif kind == "read":
            cluster.lookup_wave(kby, arrivals_by_cs=aby)
        elif kind == "rmw":
            got = cluster.lookup_wave(kby, arrivals_by_cs=aby)
            wvals = [((g.astype(np.int64) + 1) & VAL_MASK)
                     if k is not None else None
                     for k, (g, _) in zip(kby, got)]
            # the op's write is released by its own lookup's completion
            rel = [cluster.last_read_comp.get(cs) if kby[cs] is not None
                   else None for cs in range(n_cs)] \
                if cluster.clock is not None else aby
            cluster.write_wave(kby, wvals, arrivals_by_cs=rel)
        elif kind == "update":
            cluster.write_wave(kby, vby, arrivals_by_cs=aby)
        elif kind == "delete":
            cluster.write_wave(kby, None, is_delete=True,
                               arrivals_by_cs=aby)
        elif kind == "insert":
            cluster.write_wave(kby, vby, arrivals_by_cs=aby)


def run_open_loop(cluster, spec: WorkloadSpec, *, seed: int = 1,
                  keyspace: int = 1 << 20, partitioned: bool = False,
                  batch_fill: float = 0.5):
    """Drive ``spec`` through the cluster with explicit arrival times.

    Ops are materialized in the closed-loop scheduler's RNG order, given
    timestamps by ``spec.arrival`` at ``spec.offered_mops``, and thinned
    round-robin into per-CS FIFO admission queues.  The loop repeatedly
    admits up to ``per_cs`` ops per CS whose arrival is below the wave's
    formation time, dispatches them through the bucketed kind waves
    (arrivals as release gates, carried :class:`ServerClock` timeline),
    and advances to ``max(now, wave horizon)``.  With every arrival at
    t=0 this degenerates to exactly the closed-loop rounds (the
    differential test).

    Returns ``(done, op_counts, info)`` — ``info`` carries the wave
    count, absolute horizon, and last-arrival time (the offered-load
    denominator).
    """
    from repro.cluster.streams import ClusterStreams
    n_cs, per_cs = cluster.n_cs, cluster.per_cs
    opr = n_cs * per_cs
    rounds = max(1, -(-spec.ops // opr))
    n_ops = rounds * opr
    streams = ClusterStreams(spec, n_cs, keyspace=keyspace,
                             partitioned=partitioned, seed=seed)
    kinds, keys, vals = materialize_ops(spec, streams, n_cs, per_cs, rounds)
    rate = spec.offered_mops * 1e6
    arr_ps = make_arrivals(spec.arrival, max(rate, 1.0), n_ops,
                           seed=seed + 7919,
                           burst_factor=spec.burst_factor,
                           burst_frac=spec.burst_frac,
                           diurnal_period_s=spec.diurnal_period_s,
                           diurnal_peak=spec.diurnal_peak)
    # round-robin thinning: op g -> CS g % n_cs keeps every per-CS queue
    # sorted and a Poisson stream Poisson at rate/n_cs
    arr_cs = [arr_ps[cs::n_cs] / PS_PER_S for cs in range(n_cs)]

    cluster.enable_open_loop()
    qpos = np.zeros(n_cs, np.int64)
    total = rounds * per_cs
    # host batching window: dispatch when a full per-CS batch is queued
    # or the window expires — granularity only, timing-neutral (see
    # module docstring)
    window = 0.0 if spec.arrival == "closed" or rate <= 0 \
        else batch_fill * opr / rate
    now = 0.0
    waves = 0
    while (qpos < total).any():
        heads = [arr_cs[cs][qpos[cs]] if qpos[cs] < total else np.inf
                 for cs in range(n_cs)]
        horizon = max(now, min(heads) + window)
        take = []
        for cs in range(n_cs):
            lo = int(qpos[cs])
            hi = lo + int(np.searchsorted(arr_cs[cs][lo:lo + per_cs],
                                          horizon, side="right"))
            take.append((lo, hi))
        if all(hi == lo for lo, hi in take):   # pragma: no cover (guard)
            now = float(min(heads))
            continue
        _execute_wave(cluster, spec, kinds, keys, vals, arr_cs, take)
        for cs, (lo, hi) in enumerate(take):
            qpos[cs] = hi
        cluster.end_round()
        now = max(now, cluster.counters["sim_time_s"])
        waves += 1

    op_counts = {k: 0 for k in OP_KINDS}
    for cs in range(n_cs):
        for kind in KIND_ORDER:
            op_counts[kind] += int((kinds[cs] == KIND_CODE[kind]).sum())
    info = dict(waves=waves,
                horizon_s=float(cluster.counters["sim_time_s"]),
                last_arrival_s=float(arr_ps[-1]) / PS_PER_S if n_ops else 0.0,
                offered_ops_s=rate)
    return n_ops, {k: v for k, v in op_counts.items() if v}, info
