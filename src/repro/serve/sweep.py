"""Load-sweep driver: latency vs offered load, SLO attainment, and
max-sustainable-load per system (the serving-plane headline artifact,
``BENCH_load.json``).

The sweep self-calibrates: a closed-loop run per system measures each
system's drain capacity, offered-load points are placed as fractions of
the *weakest* system's capacity (so every system sees identical rates —
the curves are comparable) plus one point near the strongest system's
capacity, and the sojourn SLO is a fixed multiple of the worst closed
p99.  A rate is *sustained* when achieved/offered throughput stays above
:data:`SUSTAINED_MIN` — past saturation the absolute horizon outgrows
the arrival horizon and the ratio collapses, which is robust where SLO
attainment alone is noisy near the knee.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core import TreeConfig
from repro.workloads.engine import (DEFAULT_CFG, KEYSPACE,
                                    run_cluster_workload,
                                    run_open_loop_workload, write_json)
from repro.workloads.engine import SYSTEMS as _SYSTEMS
from repro.workloads.spec import get_preset

#: A rate counts as sustained while achieved/offered throughput >= this.
SUSTAINED_MIN = 0.95

#: Offered-load points as fractions of the weakest system's closed-loop
#: capacity: two comfortably stable, one near the knee, one past it.
LOAD_POINTS = (0.35, 0.6, 0.85, 1.15)


def load_sweep(preset: str = "write-intensive", *,
               arrival: str = "poisson",
               systems: Sequence[str] = ("sherman", "fg+"),
               n_clients: int = 16, cfg: Optional[TreeConfig] = None,
               load_records: int = 8_000, ops: int = 2_048,
               batch: Optional[int] = None, keyspace: int = KEYSPACE,
               seed: int = 1, points: Sequence[float] = LOAD_POINTS,
               slo_factor: float = 4.0,
               out: Optional[str] = "BENCH_load.json") -> dict:
    """Sweep offered load over ``systems`` and report per-rate curves.

    Returns the payload dict (also written to ``out`` unless ``None``):
    per (system, rate) a RunResult row with queueing delay separated
    from service time, plus ``capacity_mops`` (closed-loop calibration)
    and ``max_sustainable_mops`` per system.
    """
    cfg = cfg or DEFAULT_CFG
    spec = get_preset(preset, load_records=load_records, ops=ops,
                      **({"batch": batch} if batch else {}))
    for name in systems:
        if name.lower() not in _SYSTEMS:
            raise KeyError(f"unknown system {name!r}; "
                           f"known: {', '.join(sorted(_SYSTEMS))}")

    # -- closed-loop calibration: drain capacity + baseline p99 --------
    capacity, base_p99 = {}, 0.0
    for name in systems:
        r = run_cluster_workload(spec, _SYSTEMS[name.lower()],
                                 n_clients=n_clients, cfg=cfg,
                                 keyspace=keyspace, seed=seed, system=name)
        capacity[name] = r.mops
        base_p99 = max(base_p99, r.p99_us)
    lo_cap = min(capacity.values())
    hi_cap = max(capacity.values())
    slo_us = slo_factor * base_p99 if base_p99 else 100.0
    # shared axis: fractions of the weakest capacity, plus points at and
    # past the strongest system's knee so saturation is actually reached
    rates = sorted({round(f * lo_cap, 9) for f in points}
                   | {round(0.85 * hi_cap, 9), round(1.15 * hi_cap, 9)})

    # -- open-loop sweep ----------------------------------------------
    results, max_sustainable = [], {name: 0.0 for name in systems}
    for rate in rates:
        for name in systems:
            open_spec = spec.replace(arrival=arrival, offered_mops=rate)
            r = run_open_loop_workload(
                open_spec, _SYSTEMS[name.lower()], n_clients=n_clients,
                cfg=cfg, keyspace=keyspace, seed=seed, system=name,
                slo_us=slo_us)
            results.append(r)
            if r.sustained_frac >= SUSTAINED_MIN:
                max_sustainable[name] = max(max_sustainable[name], rate)

    extra = dict(kind="load_sweep", arrival=arrival, n_clients=n_clients,
                 rates_mops=list(rates), capacity_mops=capacity,
                 max_sustainable_mops=max_sustainable, slo_us=slo_us,
                 sustained_min=SUSTAINED_MIN)
    if out:
        write_json(out, spec, results, extra)
    payload = {"spec": spec.to_dict(),
               "results": [r.to_dict() for r in results], **extra}
    return payload
