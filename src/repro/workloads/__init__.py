"""Unified YCSB workload engine for the Sherman reproduction.

Declarative workload specs (:class:`WorkloadSpec`), the standard YCSB A-F
presets plus the paper's Table 3 mixes (:data:`PRESETS`), and one batched
driver (:func:`run_workload`) that prices any spec against any feature
configuration of :class:`repro.core.ShermanIndex` and emits a structured,
JSON-serializable :class:`RunResult`.

Every benchmark, example, and CI perf claim in the repo runs through this
package — see ``python -m repro.workloads --list``.
"""
from repro.workloads.engine import (DEFAULT_CFG, KEYSPACE, SYSTEMS,
                                    RunResult, build_index, live_records,
                                    run_cluster_systems,
                                    run_cluster_workload,
                                    run_open_loop_systems,
                                    run_open_loop_workload, run_systems,
                                    run_workload, write_json)
from repro.workloads.keygen import (draw_keys, latest_ranks, scramble,
                                    zipf_keys, zipf_ranks)
from repro.workloads.spec import (ARRIVAL_KINDS, OP_KINDS, PRESETS,
                                  TABLE3_PRESETS, YCSB_PRESETS,
                                  WorkloadSpec, get_preset)

__all__ = [
    "WorkloadSpec", "RunResult", "PRESETS", "YCSB_PRESETS",
    "TABLE3_PRESETS", "OP_KINDS", "ARRIVAL_KINDS", "SYSTEMS",
    "DEFAULT_CFG", "KEYSPACE",
    "get_preset", "build_index", "live_records", "run_workload",
    "run_systems", "run_cluster_workload", "run_cluster_systems",
    "run_open_loop_workload", "run_open_loop_systems",
    "write_json", "draw_keys", "zipf_keys", "zipf_ranks", "latest_ranks",
    "scramble",
]
