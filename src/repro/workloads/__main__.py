from repro.workloads.cli import main

main()
