"""``python -m repro.workloads`` — ad-hoc YCSB sweeps from the shell.

    python -m repro.workloads --preset ycsb-a --quick
    python -m repro.workloads --preset write-intensive --skew 0.9 \
        --systems sherman,fg+ --json out.json
    python -m repro.workloads --list
"""
from __future__ import annotations

import argparse
from typing import Optional

QUICK = dict(load_records=8_000, ops=1_024, batch=512)


def main(argv: Optional[list] = None) -> str:
    from repro.workloads import engine
    from repro.workloads.spec import PRESETS, get_preset

    ap = argparse.ArgumentParser(
        prog="python -m repro.workloads",
        description="Run a named YCSB/Table-3 workload against one or more "
                    "index configurations and write a BENCH_*.json.")
    ap.add_argument("--preset", default=None,
                    help=f"workload name ({', '.join(sorted(PRESETS))})")
    ap.add_argument("--list", action="store_true",
                    help="list presets and systems, then exit")
    ap.add_argument("--systems", default="sherman,fg+",
                    help="comma list of feature configs (default "
                         "sherman,fg+); see --list")
    ap.add_argument("--skew", type=float, default=None,
                    help="override the preset's zipfian theta")
    ap.add_argument("--ops", type=int, default=None,
                    help="override run-phase op count")
    ap.add_argument("--batch", type=int, default=None,
                    help="override ops per batched wave")
    ap.add_argument("--records", type=int, default=None,
                    help="override load-phase record count")
    ap.add_argument("--scan-len", type=int, default=None,
                    help="override entries per scan op")
    ap.add_argument("--cache-bytes", type=int, default=64 << 20,
                    help="CS-side index cache budget in bytes per CS "
                         "(0 disables the cache; default 64 MiB)")
    ap.add_argument("--cache-levels", type=int, default=None,
                    help="cache only the top N internal levels "
                         "(default: every internal level that fits)")
    ap.add_argument("--n-clients", type=int, default=None,
                    help="run through the multi-CS cluster plane with N "
                         "concurrent client threads spread over the "
                         "config's compute servers (private caches + "
                         "merged cross-CS contention; DESIGN.md §11)")
    ap.add_argument("--partitioned", action="store_true",
                    help="DEX-style static key partitioning across the "
                         "CSs (cluster plane only): each CS draws from "
                         "its own record shard instead of the shared "
                         "hot set")
    ap.add_argument("--arrival", default=None,
                    choices=("poisson", "bursty", "diurnal"),
                    help="open-loop serving plane (DESIGN.md §12): ops "
                         "arrive per this process at --rate instead of "
                         "draining in lockstep rounds; requires "
                         "--n-clients and --rate")
    ap.add_argument("--rate", type=float, default=None, metavar="MOPS",
                    help="offered load in Mops/s for --arrival")
    ap.add_argument("--slo-us", type=float, default=100.0,
                    help="sojourn SLO (us) used for slo_attainment in "
                         "open-loop runs (default 100)")
    ap.add_argument("--record-trace", default=None, metavar="PATH",
                    help="record the run through the observability plane "
                         "(repro.obs, DESIGN.md §14) and export a "
                         "Chrome/Perfetto trace-viewer JSON per system "
                         "(multi-system runs suffix PATH with the system "
                         "name); the BENCH json gains the obs breakdown")
    ap.add_argument("--tail-k", type=int, default=16,
                    help="top-K slowest ops kept in the tail-forensics "
                         "table (default 16; needs --record-trace)")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--quick", action="store_true",
                    help=f"CI-sized run ({QUICK})")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="output path (default BENCH_<preset>.json)")
    args = ap.parse_args(argv)

    if args.list:
        print("presets:")
        for name, s in sorted(PRESETS.items()):
            mix = ", ".join(f"{k}={v:g}" for k, v in s.fractions().items()
                            if v)
            print(f"  {name:16s} {mix}  [{s.distribution}"
                  f"{f' theta={s.theta:g}' if s.distribution != 'uniform' else ''}]")
        print("systems:", ", ".join(sorted(engine.SYSTEMS)))
        return ""
    if not args.preset:
        ap.error("--preset is required (or use --list)")

    overrides = dict(QUICK) if args.quick else {}
    for field, val in (("theta", args.skew), ("ops", args.ops),
                       ("batch", args.batch), ("load_records", args.records),
                       ("scan_len", args.scan_len)):
        if val is not None:
            if field != "theta" and val <= 0:
                ap.error(f"--{field.replace('load_records', 'records')} "
                         f"must be positive, got {val}")
            overrides[field] = val
    if args.preset not in PRESETS:
        ap.error(f"unknown preset {args.preset!r}; "
                 f"known: {', '.join(sorted(PRESETS))}")
    spec = get_preset(args.preset, **overrides)
    systems = [s.strip() for s in args.systems.split(",") if s.strip()]
    if not systems:
        ap.error("--systems is empty")
    for s in systems:                      # validate before spending time
        if s.lower() not in engine.SYSTEMS:
            ap.error(f"unknown system {s!r}; "
                     f"known: {', '.join(sorted(engine.SYSTEMS))}")

    if args.cache_bytes < 0:
        ap.error(f"--cache-bytes must be >= 0, got {args.cache_bytes}")
    if args.cache_levels is not None and args.cache_levels <= 0:
        ap.error(f"--cache-levels must be positive, got {args.cache_levels}")
    if args.n_clients is not None and args.n_clients <= 0:
        ap.error(f"--n-clients must be positive, got {args.n_clients}")
    if args.partitioned and args.n_clients is None:
        ap.error("--partitioned requires --n-clients (cluster plane)")
    if args.arrival is not None:
        if args.n_clients is None:
            ap.error("--arrival requires --n-clients (the serving plane "
                     "feeds the cluster scheduler)")
        if args.rate is None or args.rate <= 0:
            ap.error("--arrival requires a positive --rate (Mops/s)")
        spec = spec.replace(arrival=args.arrival, offered_mops=args.rate)
    elif args.rate is not None:
        ap.error("--rate only makes sense with --arrival")
    if args.slo_us <= 0:
        ap.error(f"--slo-us must be positive, got {args.slo_us}")
    if args.tail_k <= 0:
        ap.error(f"--tail-k must be positive, got {args.tail_k}")

    recorders = {} if args.record_trace else None
    rec_kw = dict(recorders=recorders, tail_k=args.tail_k)
    if args.arrival is not None:
        results = engine.run_open_loop_systems(
            spec, systems, n_clients=args.n_clients, seed=args.seed,
            cache_bytes=args.cache_bytes, cache_levels=args.cache_levels,
            partitioned=args.partitioned, slo_us=args.slo_us, **rec_kw)
    elif args.n_clients is not None:
        results = engine.run_cluster_systems(
            spec, systems, n_clients=args.n_clients, seed=args.seed,
            cache_bytes=args.cache_bytes, cache_levels=args.cache_levels,
            partitioned=args.partitioned, **rec_kw)
    else:
        results = engine.run_systems(spec, systems, seed=args.seed,
                                     cache_bytes=args.cache_bytes,
                                     cache_levels=args.cache_levels,
                                     **rec_kw)
    print(f"{'system':18s} {'Mops':>8s} {'p50us':>8s} {'p99us':>10s} "
          f"{'dbl50':>6s} {'wr.B':>7s} {'hit%':>6s} {'rd/l':>5s} "
          f"{'dbells':>8s} {'saved':>7s}")
    for r in results:
        print(f"{r.system:18s} {r.mops:8.2f} {r.p50_us:8.1f} "
              f"{r.p99_us:10.1f} {r.doorbells_p50:6.0f} "
              f"{r.write_bytes_median:7.0f} {100 * r.cache_hit_rate:6.1f} "
              f"{r.reads_per_lookup:5.2f} {r.doorbells:8d} "
              f"{r.doorbells_saved:7d}")
        if r.per_cs:
            stale = sum(p["cache_stale"] for p in r.per_cs)
            print(f"  cluster: {len(r.per_cs)} CS x "
                  f"{r.n_clients // len(r.per_cs)} threads, "
                  f"{r.rounds} rounds, stale={stale}, "
                  f"conservation={'OK' if r.conservation_ok else 'VIOLATED'}")
        if r.arrival != "closed":
            print(f"  open loop: {r.arrival} @ {r.offered_mops:.2f} Mops "
                  f"offered, queue mean/p99 = {r.queue_mean_us:.2f}/"
                  f"{r.queue_p99_us:.2f} us, service mean = "
                  f"{r.service_mean_us:.2f} us, SLO({r.slo_us:.0f}us) "
                  f"attainment = {100 * r.slo_attainment:.1f}%, "
                  f"sustained = {100 * r.sustained_frac:.1f}%")

    if recorders:
        from repro.obs import write_chrome_trace
        for r in results:
            rec = recorders.get(r.system)
            if rec is None:
                continue
            tp = args.record_trace
            if len(results) > 1:            # one trace file per system
                stem, dot, ext = tp.rpartition(".")
                tp = (f"{stem}.{r.system}.{ext}" if dot
                      else f"{tp}.{r.system}")
            write_chrome_trace(rec, tp)
            a = r.obs.get("tail_attribution", {})
            print(f"  trace: {tp} ({rec.n_verbs} verbs, "
                  f"tail p99 attribution: "
                  f"nic={100 * a.get('nic_queue_frac', 0):.0f}% "
                  f"atomic={100 * a.get('atomic_ser_frac', 0):.0f}% "
                  f"lock={100 * a.get('lock_wait_frac', 0):.0f}% "
                  f"svc={100 * a.get('service_frac', 0):.0f}%)")

    path = args.json or f"BENCH_{spec.name.replace('-', '_')}.json"
    engine.write_json(path, spec, results)
    print(f"wrote {path}")
    return path


if __name__ == "__main__":
    main()
