"""The workload driver: run any :class:`WorkloadSpec` against the index.

One engine for every perf claim in the repo: benchmarks, examples, and CI
all come through :func:`run_workload`, which executes the spec's op mix in
batched waves and derives a structured :class:`RunResult` (throughput,
latency percentiles, doorbell depth, write bytes, per-op-type counters)
from the index's netsim counters.  Results serialize to ``BENCH_*.json``
via :func:`write_json`.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Sequence

import numpy as np

from repro.core import ShermanIndex, TreeConfig
from repro.core.netsim import ABLATION_LADDER, FG_PLUS, SHERMAN, Features
from repro.workloads.keygen import draw_keys, scramble
from repro.workloads.spec import OP_KINDS, WorkloadSpec

KEYSPACE = 1 << 20            # power of two => rank scramble is a bijection
DEFAULT_CFG = TreeConfig(n_ms=4, nodes_per_ms=4096, fanout=16,
                         n_locks_per_ms=4096, max_height=7, n_cs=8)
VAL_MASK = (1 << 30) - 1

#: Named feature configurations runnable from the CLI / benchmarks:
#: ``sherman``, ``fg+``, the Fig. 10/11 ablation rungs (``+combine``,
#: ``+on-chip``, ``+hierarchical``, ``+2-level ver``), plus single-feature
#: negations of full Sherman for the verb-plane acceptance checks
#: (``sherman-nocombine`` — doorbell merging off; ``sherman-flat`` — lock
#: hierarchy off, every waiter spins).
SYSTEMS: dict[str, Features] = {
    "sherman": SHERMAN,
    "fg+": FG_PLUS,
    "sherman-nocombine": Features(combine=False, onchip=True,
                                  hierarchical=True, twolevel=True),
    "sherman-flat": Features(combine=True, onchip=True,
                             hierarchical=False, twolevel=True),
    **{name.lower(): feat for name, feat in ABLATION_LADDER},
}


@dataclasses.dataclass
class RunResult:
    """Structured result of one workload run (one system, one spec)."""

    mops: float
    p50_us: float
    p90_us: float
    p99_us: float
    counters: dict
    system: str = ""
    workload: str = ""
    n_ops: int = 0
    read_p50_us: float = 0.0
    read_p99_us: float = 0.0
    write_p50_us: float = 0.0
    write_p99_us: float = 0.0
    # Doorbell-ring depth per write op (netsim ``lane_doorbells``): the
    # sequential posting-depth metric.  Until PR 5 these fields were
    # (mis)named ``rtt_p50``/``rtt_p99`` — the value was always doorbell
    # rings, which only coincide with round trips when nothing combines.
    doorbells_p50: float = 0.0
    doorbells_p99: float = 0.0
    write_bytes_median: float = 0.0
    op_counts: dict = dataclasses.field(default_factory=dict)
    # CS-side index cache outcome of this run (repro.core.cache):
    cache_hits: int = 0          # lookups served by a clean cache hit
    cache_misses: int = 0        # descents that left the cached set
    cache_stale: int = 0         # hits recovered via the stale path
    cache_hit_rate: float = 0.0  # hits / (hits + misses + stale)
    reads_per_lookup: float = 0.0  # mean remote node reads per point lookup
    # RDMA verb-trace plane (repro.core.verbs / netsim event loop):
    verbs: int = 0               # one-sided verbs posted (READ/WRITE/CAS)
    doorbells: int = 0           # doorbell rings (combined verbs share one)
    doorbells_saved: int = 0     # rings saved by command combination
    retried_ops: int = 0         # lanes resubmitted by later write phases
    # Multi-CS cluster plane (repro.cluster, DESIGN.md §11); single-frontend
    # runs report n_clients=0, rounds=0, per_cs=[]:
    n_clients: int = 0           # realized client threads per round
    rounds: int = 0              # scheduler ticks executed
    per_cs: list = dataclasses.field(default_factory=list)
    #                            ^ per-CS breakdown (ops, verbs, cache, ...)
    conservation_ok: bool = True  # merged-trace totals == sum of per-CS
    #                            functional trace totals (always True for
    #                            single-frontend runs — nothing is merged)
    # Open-loop serving plane (repro.serve, DESIGN.md §12); closed-loop
    # runs report arrival="closed" and zeros:
    arrival: str = "closed"      # arrival process driving the run
    offered_mops: float = 0.0    # offered load (0 for closed loop)
    queue_mean_us: float = 0.0   # mean NIC/atomic queueing delay per op
    queue_p50_us: float = 0.0
    queue_p99_us: float = 0.0
    service_mean_us: float = 0.0  # mean sojourn minus mean queueing
    slo_us: float = 0.0          # sojourn SLO this run was judged against
    slo_attainment: float = 0.0  # fraction of ops with sojourn <= slo_us
    sustained_frac: float = 0.0  # achieved/offered throughput (<= 1)
    # Observability plane (repro.obs, DESIGN.md §14); empty unless a
    # Recorder was attached to the run.  Carries the aggregate latency
    # attribution (NIC queue / atomic serialization / lock wait /
    # service), the top-K tail-forensics table, per-MS utilization, and
    # the span-conservation verdict (repro.obs.metrics.summarize).
    obs: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return _pyify(dataclasses.asdict(self))


def _pyify(x):
    """Recursively convert numpy scalars so the result is json-safe."""
    if isinstance(x, dict):
        return {k: _pyify(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_pyify(v) for v in x]
    if isinstance(x, np.integer):
        return int(x)
    if isinstance(x, np.floating):
        return float(x)
    return x


def build_index(features: Features, cfg: TreeConfig = DEFAULT_CFG, *,
                records: int = 60_000, keyspace: int = KEYSPACE,
                cache_bytes: int = 64 << 20,
                cache_levels: Optional[int] = None, seed: int = 0,
                fill: float = 0.8) -> ShermanIndex:
    """Load phase: bulk-load ``records`` records (insertion ranks
    ``0..records`` scrambled across the keyspace, YCSB-style)."""
    rng = np.random.default_rng(seed)
    keys = scramble(np.arange(records, dtype=np.int64), keyspace)
    vals = rng.integers(0, VAL_MASK, size=records)
    return ShermanIndex.build(cfg, keys, vals, fill=fill, features=features,
                              cache_bytes=cache_bytes,
                              cache_levels=cache_levels)


def live_records(idx: ShermanIndex) -> int:
    """Count live leaf entries — the record-space size for distribution
    draws when the index wasn't built by :func:`build_index`'s load phase."""
    from repro.core.tree import EMPTY_KEY
    leaf = (np.asarray(idx.state.level) == 0) & \
        ~np.asarray(idx.state.free_bit)
    return int((np.asarray(idx.state.keys)[leaf] != EMPTY_KEY).sum())


def _batch_counts(spec: WorkloadSpec, b: int) -> dict:
    """Deterministic per-batch op counts (now a spec method; kept as a
    module-level alias for existing callers)."""
    return spec.batch_counts(b)


def _obs_summary(recorder, tail_k: int) -> dict:
    """``RunResult.obs`` payload for an optionally-recorded run."""
    if recorder is None:
        return {}
    from repro.obs import summarize
    return summarize(recorder, tail_k=tail_k)


def run_workload(idx: ShermanIndex, spec: WorkloadSpec, *, seed: int = 1,
                 keyspace: int = KEYSPACE, system: str = "",
                 recorder=None, tail_k: int = 16) -> RunResult:
    """Run ``spec``'s op mix against ``idx`` and price it via netsim.

    The result reports only this run's deltas, so several runs may share one
    index (e.g. a warmup pass followed by a measured pass).  ``recorder``
    (a :class:`repro.obs.Recorder`) opts into the observability plane:
    every priced phase captures its per-verb timeline and the result's
    ``obs`` field carries the aggregate breakdown (top-``tail_k``
    forensics included).
    """
    rng = np.random.default_rng(seed)
    if recorder is not None:
        idx.recorder = recorder
    c0 = dict(idx.counters)
    lw0, lr0 = len(idx.latencies_write), len(idx.latencies_read)
    db0, wb0 = len(idx.doorbells_write), len(idx.write_bytes)

    n_records = spec.load_records      # live records (grows with inserts)
    cursor = spec.load_records         # next sequential insertion rank
    op_counts = {k: 0 for k in OP_KINDS}

    def draw(n):
        return draw_keys(rng, n, distribution=spec.distribution,
                         theta=spec.theta, nspace=n_records,
                         keyspace=keyspace).astype(np.int32)

    done = 0
    while done < spec.ops:
        b = min(spec.batch, spec.ops - done)
        counts = _batch_counts(spec, b)
        if counts["scan"]:
            idx.range(draw(counts["scan"]), count=spec.scan_len,
                      max_leaves=max(4, spec.scan_len))
        if counts["read"]:
            idx.lookup(draw(counts["read"]))
        if counts["rmw"]:
            keys = draw(counts["rmw"])
            got, _ = idx.lookup(keys)
            idx.insert(keys, (got.astype(np.int64) + 1) & VAL_MASK)
        if counts["update"]:
            keys = draw(counts["update"])
            idx.insert(keys, rng.integers(0, VAL_MASK, keys.size))
        if counts["delete"]:
            idx.delete(draw(counts["delete"]))
        if counts["insert"]:
            ranks = np.arange(cursor, cursor + counts["insert"])
            cursor += counts["insert"]
            n_records = max(n_records, cursor)
            idx.insert(scramble(ranks, keyspace).astype(np.int32),
                       rng.integers(0, VAL_MASK, ranks.size))
        for k in OP_KINDS:
            op_counts[k] += counts[k]
        done += b

    sim_s = idx.counters["sim_time_s"] - c0.get("sim_time_s", 0.0)
    lat_w = _cat(idx.latencies_write[lw0:])
    lat_r = _cat(idx.latencies_read[lr0:])
    dbells = _cat(idx.doorbells_write[db0:])
    wb = _cat(idx.write_bytes[wb0:])
    delta = {k: idx.counters[k] - c0.get(k, 0) for k in idx.counters}
    return _summarize(spec, delta, done, sim_s, lat_w, lat_r, dbells, wb,
                      system=system,
                      op_counts={k: v for k, v in op_counts.items() if v},
                      obs=_obs_summary(recorder, tail_k))


def _cat(arrs) -> np.ndarray:
    """Concatenate a (possibly empty) list of per-phase sample arrays.
    Empty runs yield a size-0 array — every percentile over it is guarded
    (the ``doorbells_p50``/``doorbells_p99`` empty-run crash fix)."""
    return np.concatenate(arrs) if arrs else np.zeros(0)


def _summarize(spec: WorkloadSpec, delta: dict, done: int, sim_s: float,
               lat_w, lat_r, dbells, wb, *, system: str = "",
               op_counts: Optional[dict] = None, **extra) -> RunResult:
    """Fold one run's counter deltas + latency samples into a RunResult.
    Shared by the single-frontend and cluster drivers; all percentile
    reductions are guarded against empty sample sets, and throughput is
    0.0 (never ``inf``) when nothing was priced."""
    lat = np.concatenate([lat_w, lat_r])

    def pct(a, p, scale=1e6):
        return float(np.percentile(a, p)) * scale if a.size else 0.0

    cache_total = (delta["cache_hits"] + delta["cache_misses"]
                   + delta["cache_stale"])
    return RunResult(
        mops=done / sim_s / 1e6 if sim_s else 0.0,
        p50_us=pct(lat, 50), p90_us=pct(lat, 90), p99_us=pct(lat, 99),
        counters=delta, system=system, workload=spec.name, n_ops=done,
        read_p50_us=pct(lat_r, 50), read_p99_us=pct(lat_r, 99),
        write_p50_us=pct(lat_w, 50), write_p99_us=pct(lat_w, 99),
        doorbells_p50=pct(dbells, 50, 1.0),
        doorbells_p99=pct(dbells, 99, 1.0),
        write_bytes_median=float(np.median(wb)) if wb.size else 0.0,
        op_counts=op_counts or {},
        cache_hits=delta["cache_hits"], cache_misses=delta["cache_misses"],
        cache_stale=delta["cache_stale"],
        cache_hit_rate=(delta["cache_hits"] / cache_total
                        if cache_total else 0.0),
        reads_per_lookup=(delta["lookup_reads"] / delta["lookup_ops"]
                          if delta["lookup_ops"] else 0.0),
        verbs=delta["verbs"], doorbells=delta["doorbells"],
        doorbells_saved=delta["verbs"] - delta["doorbells"],
        retried_ops=delta["retried_ops"], **extra)


def _new_recorder(recorders: Optional[dict], name: str):
    """A fresh per-system Recorder when the caller opted into recording
    by passing a ``recorders`` dict (filled in as an out-parameter so
    the CLI can export the captured timelines)."""
    if recorders is None:
        return None
    from repro.obs import Recorder
    recorders[name] = Recorder()
    return recorders[name]


def run_systems(spec: WorkloadSpec, systems: Sequence[str] = ("sherman",
                                                              "fg+"),
                cfg: TreeConfig = DEFAULT_CFG, *, keyspace: int = KEYSPACE,
                cache_bytes: int = 64 << 20,
                cache_levels: Optional[int] = None,
                seed: int = 1, recorders: Optional[dict] = None,
                tail_k: int = 16) -> list[RunResult]:
    """Run one spec against several named systems (fresh index each)."""
    out = []
    for name in systems:
        try:
            feat = SYSTEMS[name.lower()]
        except KeyError:
            raise KeyError(f"unknown system {name!r}; "
                           f"known: {', '.join(sorted(SYSTEMS))}") from None
        idx = build_index(feat, cfg, records=spec.load_records,
                          keyspace=keyspace, cache_bytes=cache_bytes,
                          cache_levels=cache_levels)
        out.append(run_workload(idx, spec, seed=seed, keyspace=keyspace,
                                system=name,
                                recorder=_new_recorder(recorders, name),
                                tail_k=tail_k))
    return out


def run_cluster_workload(spec: WorkloadSpec, features: Features, *,
                         n_clients: int, cfg: TreeConfig = DEFAULT_CFG,
                         keyspace: int = KEYSPACE,
                         cache_bytes: int = 64 << 20,
                         cache_levels: Optional[int] = None,
                         partitioned: bool = False, sync_rounds: int = 4,
                         seed: int = 1, system: str = "",
                         recorder=None, tail_k: int = 16) -> RunResult:
    """Run one spec through the multi-CS cluster plane (DESIGN.md §11).

    ``n_clients`` concurrent client threads are spread over
    ``min(cfg.n_cs, n_clients)`` compute servers, each with a private
    index cache / LLT view; every wave is priced by merging the
    fleet's verb traces into one shared-resource timeline.  The result
    carries the per-CS breakdown (``per_cs``) and the merged-vs-functional
    ``conservation_ok`` invariant.
    """
    from repro.cluster import build_cluster, run_cluster
    cluster = build_cluster(features, cfg, n_clients=n_clients,
                            records=spec.load_records, keyspace=keyspace,
                            cache_bytes=cache_bytes,
                            cache_levels=cache_levels,
                            sync_rounds=sync_rounds, seed=0)
    cluster.recorder = recorder
    done, op_counts = run_cluster(cluster, spec, partitioned=partitioned,
                                  seed=seed, keyspace=keyspace)
    delta = cluster.combined_counters()
    per_cs = _per_cs_rows(cluster)
    return _summarize(
        spec, delta, done, delta["sim_time_s"],
        _cat(cluster.latencies_write), _cat(cluster.latencies_read),
        _cat(cluster.doorbells_write), _cat(cluster.write_bytes),
        system=system, op_counts=op_counts, n_clients=cluster.n_clients,
        rounds=delta["rounds"], per_cs=per_cs,
        conservation_ok=cluster.conservation_ok(),
        obs=_obs_summary(recorder, tail_k))


def _per_cs_rows(cluster) -> list:
    """Per-CS breakdown rows shared by the cluster + open-loop drivers."""
    rows = []
    for node in cluster.nodes:
        c = node.counters
        t = c["cache_hits"] + c["cache_misses"] + c["cache_stale"]
        rows.append(dict(
            cs=node.cs_id, ops=c["ops"], write_ops=c["write_ops"],
            read_ops=c["read_ops"], retried_ops=c["retried_ops"],
            verbs=c["verbs"], doorbells=c["doorbells"],
            leaf_splits=c["leaf_splits"], handovers=c["handovers"],
            cache_hits=c["cache_hits"], cache_misses=c["cache_misses"],
            cache_stale=c["cache_stale"],
            cache_hit_rate=c["cache_hits"] / t if t else 0.0))
    return rows


def run_open_loop_workload(spec: WorkloadSpec, features: Features, *,
                           n_clients: int, cfg: TreeConfig = DEFAULT_CFG,
                           keyspace: int = KEYSPACE,
                           cache_bytes: int = 64 << 20,
                           cache_levels: Optional[int] = None,
                           partitioned: bool = False, sync_rounds: int = 4,
                           seed: int = 1, system: str = "",
                           slo_us: float = 100.0,
                           recorder=None, tail_k: int = 16) -> RunResult:
    """Run one spec open-loop through the serving plane (DESIGN.md §12).

    Ops arrive per ``spec.arrival`` / ``spec.offered_mops`` instead of
    being drained in lockstep rounds: the admission loop feeds the same
    bucketed jitted cluster waves as arrivals drain, waves replay on one
    absolute :class:`~repro.core.netsim.ServerClock` timeline, and every
    op's latency is its *sojourn* (arrival → completion) with the
    NIC/atomic queueing share reported separately
    (``queue_*`` vs ``service_mean_us``).
    """
    from repro.cluster import build_cluster
    from repro.serve.loop import run_open_loop
    cluster = build_cluster(features, cfg, n_clients=n_clients,
                            records=spec.load_records, keyspace=keyspace,
                            cache_bytes=cache_bytes,
                            cache_levels=cache_levels,
                            sync_rounds=sync_rounds, seed=0)
    cluster.recorder = recorder   # enable_open_loop hands it to the clock
    done, op_counts, info = run_open_loop(cluster, spec, seed=seed,
                                          keyspace=keyspace,
                                          partitioned=partitioned)
    delta = cluster.combined_counters()
    lat_w = _cat(cluster.latencies_write)
    lat_r = _cat(cluster.latencies_read)
    lat = np.concatenate([lat_w, lat_r])
    q = np.concatenate([_cat(cluster.queue_write),
                        _cat(cluster.queue_read)])
    horizon = delta["sim_time_s"]
    achieved = done / horizon / 1e6 if horizon else 0.0
    offered = info["offered_ops_s"] / 1e6
    res = _summarize(
        spec, delta, done, horizon, lat_w, lat_r,
        _cat(cluster.doorbells_write), _cat(cluster.write_bytes),
        system=system, op_counts=op_counts, n_clients=cluster.n_clients,
        rounds=info["waves"], per_cs=_per_cs_rows(cluster),
        conservation_ok=cluster.conservation_ok(),
        arrival=spec.arrival, offered_mops=offered,
        queue_mean_us=float(q.mean()) * 1e6 if q.size else 0.0,
        queue_p50_us=float(np.percentile(q, 50)) * 1e6 if q.size else 0.0,
        queue_p99_us=float(np.percentile(q, 99)) * 1e6 if q.size else 0.0,
        service_mean_us=(float(lat.mean() - q.mean()) * 1e6
                         if lat.size and q.size else 0.0),
        slo_us=slo_us,
        slo_attainment=(float((lat <= slo_us * 1e-6).mean())
                        if lat.size else 0.0),
        sustained_frac=(min(1.0, achieved / offered) if offered else 1.0),
        obs=_obs_summary(recorder, tail_k))
    return res


def run_open_loop_systems(spec: WorkloadSpec,
                          systems: Sequence[str] = ("sherman", "fg+"),
                          cfg: TreeConfig = DEFAULT_CFG, *,
                          n_clients: int, keyspace: int = KEYSPACE,
                          cache_bytes: int = 64 << 20,
                          cache_levels: Optional[int] = None,
                          partitioned: bool = False, sync_rounds: int = 4,
                          seed: int = 1,
                          slo_us: float = 100.0,
                          recorders: Optional[dict] = None,
                          tail_k: int = 16) -> list[RunResult]:
    """Open-loop analogue of :func:`run_cluster_systems`."""
    out = []
    for name in systems:
        try:
            feat = SYSTEMS[name.lower()]
        except KeyError:
            raise KeyError(f"unknown system {name!r}; "
                           f"known: {', '.join(sorted(SYSTEMS))}") from None
        out.append(run_open_loop_workload(
            spec, feat, n_clients=n_clients, cfg=cfg, keyspace=keyspace,
            cache_bytes=cache_bytes, cache_levels=cache_levels,
            partitioned=partitioned, sync_rounds=sync_rounds, seed=seed,
            system=name, slo_us=slo_us,
            recorder=_new_recorder(recorders, name), tail_k=tail_k))
    return out


def run_cluster_systems(spec: WorkloadSpec,
                        systems: Sequence[str] = ("sherman", "fg+"),
                        cfg: TreeConfig = DEFAULT_CFG, *,
                        n_clients: int, keyspace: int = KEYSPACE,
                        cache_bytes: int = 64 << 20,
                        cache_levels: Optional[int] = None,
                        partitioned: bool = False, sync_rounds: int = 4,
                        seed: int = 1, recorders: Optional[dict] = None,
                        tail_k: int = 16) -> list[RunResult]:
    """Cluster-plane analogue of :func:`run_systems` (fresh fleet each)."""
    out = []
    for name in systems:
        try:
            feat = SYSTEMS[name.lower()]
        except KeyError:
            raise KeyError(f"unknown system {name!r}; "
                           f"known: {', '.join(sorted(SYSTEMS))}") from None
        out.append(run_cluster_workload(
            spec, feat, n_clients=n_clients, cfg=cfg, keyspace=keyspace,
            cache_bytes=cache_bytes, cache_levels=cache_levels,
            partitioned=partitioned, sync_rounds=sync_rounds, seed=seed,
            system=name, recorder=_new_recorder(recorders, name),
            tail_k=tail_k))
    return out


def write_json(path: str, spec: WorkloadSpec,
               results: Sequence[RunResult],
               extra: Optional[dict] = None) -> str:
    """Serialize a sweep to a ``BENCH_*.json`` file; returns the path."""
    payload = {"spec": spec.to_dict(),
               "results": [r.to_dict() for r in results]}
    if extra:
        payload.update(_pyify(extra))
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path
