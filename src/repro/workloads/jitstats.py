"""XLA compile-count instrumentation for the shape-stability discipline.

Every jitted entry point in the hot path (:mod:`repro.core.api`) pads its
batch to a power-of-two bucket and keeps the repair queue at a fixed
capacity, so a steady-state workload must not trigger *any* fresh XLA
compilation after its warmup batch.  This module counts compilations so
benchmarks (``benchmarks/run.py --only throughput``) can report them and
CI / tests (``tests/test_throughput.py``) can regress on them.

The count hooks ``MeshComputation.compile`` — the single funnel every
XLA build passes through on the jax pinned in this container (0.4.x);
jit-cache hits never reach it, so the tally is *distinct compilations*,
not dispatches.  (``jax.monitoring`` events were considered and
rejected: on this version they fire per compile *request* — cache hits
included — and listeners cannot be unregistered.)  If a future jax
moves the internals, :func:`count_compiles` degrades to ``available =
False`` / ``count == -1`` rather than miscounting, and the consumers
skip their assertions.
"""
from __future__ import annotations

import contextlib
import dataclasses


@dataclasses.dataclass
class CompileStats:
    """Mutable compile tally, readable while the context is active."""
    count: int = 0
    available: bool = True


@contextlib.contextmanager
def count_compiles():
    """Count XLA compilations (not jit-cache hits) inside the context.

    >>> with count_compiles() as stats:
    ...     run_workload(...)
    >>> stats.count
    """
    stats = CompileStats()
    try:
        from jax._src.interpreters import pxla
        orig = pxla.MeshComputation.compile
    except Exception:
        stats.available = False
        stats.count = -1
        yield stats
        return

    def counted(self, *a, **kw):
        stats.count += 1
        return orig(self, *a, **kw)

    pxla.MeshComputation.compile = counted
    try:
        yield stats
    finally:
        pxla.MeshComputation.compile = orig
