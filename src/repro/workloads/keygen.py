"""Key generators for the YCSB workload engine.

Records are identified by an insertion *rank* (0 = first record loaded);
ranks are mapped to keys with a Knuth multiplicative scramble so that hot
ranks land far apart in key space (exactly what YCSB's ScrambledZipfian
does, and what keeps skewed workloads from turning into sequential-leaf
workloads).  The scramble is a bijection on ``[0, keyspace)`` whenever
``keyspace`` is a power of two, so rank-space draws never alias.

Three distributions, matching the YCSB core generators:

* ``zipfian`` — Gray et al.'s ZipfianGenerator; rank 0 receives ~1/zeta of
  all accesses (≈6-7% at theta=0.99 over 2^20 keys).
* ``uniform`` — every live record equally likely.
* ``latest``  — zipfian over recency: the most recently inserted records
  are the hottest (YCSB-D's read pattern).
"""
from __future__ import annotations

import numpy as np

SCRAMBLE = 2_654_435_761  # odd => bijective modulo any power of two

_ZETA_CACHE: dict = {}


def zeta(n: int, theta: float) -> float:
    """zeta(n, theta) with an integral tail approximation (fast + exact
    enough for the YCSB generator)."""
    key = (n, theta)
    if key not in _ZETA_CACHE:
        head = np.sum(1.0 / np.arange(1, 10_001) ** theta) \
            if n > 10_000 else np.sum(1.0 / np.arange(1, n + 1) ** theta)
        tail = ((n ** (1 - theta) - 10_000 ** (1 - theta)) / (1 - theta)
                if n > 10_000 else 0.0)
        _ZETA_CACHE[key] = float(head + tail)
    return _ZETA_CACHE[key]


def zipf_ranks(rng, n: int, nspace: int, theta: float) -> np.ndarray:
    """YCSB ZipfianGenerator (Gray et al.), vectorized; unscrambled ranks."""
    nspace = max(int(nspace), 1)
    if theta <= 0.0:
        return rng.integers(0, nspace, size=n).astype(np.int64)
    if abs(theta - 1.0) < 1e-9:
        theta = 1.0 - 1e-6   # the Gray generator is singular at theta=1
    zetan = zeta(nspace, theta)
    zeta2 = zeta(2, theta)
    alpha = 1.0 / (1.0 - theta)
    eta = (1 - (2.0 / nspace) ** (1 - theta)) / (1 - zeta2 / zetan)
    u = rng.random(n)
    uz = u * zetan
    ranks = np.where(
        uz < 1.0, 0,
        np.where(uz < 1.0 + 0.5 ** theta, 1,
                 (nspace * (eta * u - eta + 1) ** alpha).astype(np.int64)))
    return np.clip(ranks, 0, nspace - 1).astype(np.int64)


def latest_ranks(rng, n: int, nspace: int, theta: float) -> np.ndarray:
    """YCSB SkewedLatestGenerator: zipfian over recency — rank
    ``nspace-1`` (the newest record) is the hottest."""
    return np.maximum(0, nspace - 1 - zipf_ranks(rng, n, nspace, theta))


def hotspot_ranks(rng, n: int, nspace: int, hot_frac: float,
                  hot_n: int) -> np.ndarray:
    """YCSB HotspotGenerator: a ``hot_frac`` share of accesses hits a
    fixed hot set of ``hot_n`` ranks, the rest is uniform over the whole
    space.  The chaos plane's hot-key *storms* are skew shifts onto this
    distribution — far spikier than any zipfian theta, concentrating the
    fleet on a handful of leaves (DESIGN.md §13)."""
    nspace = max(int(nspace), 1)
    hot_n = max(1, min(int(hot_n), nspace))
    hot = rng.random(n) < hot_frac
    ranks = rng.integers(0, nspace, size=n).astype(np.int64)
    return np.where(hot, rng.integers(0, hot_n, size=n).astype(np.int64),
                    ranks)


def scramble(ranks: np.ndarray, keyspace: int) -> np.ndarray:
    """Map insertion ranks to keys (deterministic scatter across keyspace)."""
    return ((np.asarray(ranks, np.int64) * SCRAMBLE) % keyspace
            ).astype(np.int64)


def draw_keys(rng, n: int, *, distribution: str, theta: float,
              nspace: int, keyspace: int, hot_frac: float = 0.9,
              hot_n: int = 64) -> np.ndarray:
    """Draw ``n`` keys of live records under the given distribution."""
    if distribution == "uniform":
        ranks = rng.integers(0, max(nspace, 1), size=n).astype(np.int64)
    elif distribution == "latest":
        ranks = latest_ranks(rng, n, nspace, theta)
    elif distribution == "zipfian":
        ranks = zipf_ranks(rng, n, nspace, theta)
    elif distribution == "hotspot":
        ranks = hotspot_ranks(rng, n, nspace, hot_frac, hot_n)
    else:
        raise ValueError(f"unknown distribution: {distribution!r}")
    return scramble(ranks, keyspace)


def zipf_keys(rng, n: int, keyspace: int, theta: float) -> np.ndarray:
    """Back-compat helper (the seed benchmark API): scrambled zipfian keys
    drawn over the whole keyspace."""
    if theta <= 0.0:
        return rng.integers(0, keyspace, size=n).astype(np.int64)
    return scramble(zipf_ranks(rng, n, keyspace, theta), keyspace)
