"""Workload specifications: the YCSB A-F suite plus Sherman's Table 3 mixes.

A :class:`WorkloadSpec` is a declarative description of a key-value workload
— operation mix, key distribution, scan length, and load/run-phase sizes —
that the engine (:mod:`repro.workloads.engine`) can run against any feature
configuration of the index.  All named mixes used anywhere in the repo live
here; benchmarks and examples must not carry private copies.

Operation semantics (mapped onto the batched ``ShermanIndex`` API):

* ``read``    — point lookup of a live record.
* ``update``  — write to a live record drawn from the distribution (this is
  what the paper's skewed-write workloads stress: hot-leaf contention).
* ``insert``  — append a brand-new record (sequential insertion rank, the
  YCSB insert semantics; grows the live-record count).
* ``delete``  — remove a live record.
* ``scan``    — short ordered range scan of ``scan_len`` entries.
* ``rmw``     — read-modify-write: lookup then write back to the same key.
"""
from __future__ import annotations

import dataclasses

OP_KINDS = ("read", "insert", "update", "delete", "scan", "rmw")
DISTRIBUTIONS = ("zipfian", "uniform", "latest", "hotspot")
#: Arrival processes for the open-loop serving plane (repro.serve);
#: canonical here so the spec validates without importing the plane.
ARRIVAL_KINDS = ("closed", "poisson", "bursty", "diurnal")
#: Fault kinds the chaos plane (repro.chaos; DESIGN.md §13) can inject;
#: canonical here — like ARRIVAL_KINDS — so a spec carrying a fault
#: schedule validates without importing the plane.
FAULT_KINDS = ("ms_crash", "cs_leave", "cs_join", "skew_shift")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One declarative fault on the simulation's shared time grid.

    The chaos plane (:mod:`repro.chaos`) fires the event at the first
    scheduler-round boundary whose simulated time has reached ``at_s``
    (crash *effects* land mid-wave — see ``ChaosRunner``).  Which extra
    fields matter depends on ``kind``:

    * ``ms_crash``   — ``ms`` crashes, losing its HOCL on-chip lock table
      (GLT rows) and, with ``lose_memory``, its share of the pooled
      memory (recovery then restores the last checkpoint and replays the
      redo log); the server restarts ``down_s`` simulated seconds later
      with an empty NIC.
    * ``cs_leave``   — compute server ``cs`` leaves; its op stream fails
      over to the surviving CSs.
    * ``cs_join``    — ``cs`` (re)joins with a **cold** index cache.
    * ``skew_shift`` — the key distribution changes from here on
      (``distribution``/``theta``/``hot_frac``/``hot_n``; empty/negative
      fields keep the current value).  A hot-key storm is a shift onto
      ``hotspot`` and a later shift back.
    """

    kind: str
    at_s: float
    ms: int = 0                  # ms_crash target
    down_s: float = 0.0          # ms_crash restart delay
    lose_memory: bool = False    # ms_crash: pooled memory lost too
    cs: int = 0                  # cs_leave / cs_join target
    distribution: str = ""       # skew_shift ("" = keep current)
    theta: float = -1.0          # skew_shift (< 0 = keep current)
    hot_frac: float = -1.0       # skew_shift hotspot share (< 0 = keep)
    hot_n: int = 0               # skew_shift hot-set size (0 = keep)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(want one of {FAULT_KINDS})")
        if self.at_s < 0:
            raise ValueError(f"fault at_s must be >= 0, got {self.at_s}")
        if self.kind == "ms_crash" and self.down_s < 0:
            raise ValueError(f"ms_crash down_s must be >= 0, "
                             f"got {self.down_s}")
        if self.kind == "skew_shift" and self.distribution \
                and self.distribution not in DISTRIBUTIONS:
            raise ValueError(
                f"skew_shift distribution {self.distribution!r} "
                f"unknown (want one of {DISTRIBUTIONS})")


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """One workload: op-mix fractions must sum to 1."""

    name: str
    read: float = 0.0
    insert: float = 0.0
    update: float = 0.0
    delete: float = 0.0
    scan: float = 0.0
    rmw: float = 0.0
    distribution: str = "zipfian"   # zipfian | uniform | latest | hotspot
    theta: float = 0.99             # zipfian/latest skew (0 => uniform)
    hot_frac: float = 0.9           # hotspot: share of ops on the hot set
    hot_n: int = 64                 # hotspot: hot-set size (records)
    scan_len: int = 10              # entries per scan op
    load_records: int = 60_000      # records bulk-loaded before the run
    ops: int = 8_192                # run-phase operation count
    batch: int = 1_024              # ops per batched wave

    # -- chaos plane (repro.chaos; DESIGN.md §13) ----------------------
    faults: tuple = ()              # FaultEvent schedule (empty = no faults)

    # -- open-loop serving plane (repro.serve; DESIGN.md §12) ----------
    arrival: str = "closed"         # closed | poisson | bursty | diurnal
    offered_mops: float = 0.0       # offered load (Mops/s); >0 when open
    burst_factor: float = 8.0       # bursty: burst-state rate multiplier
    burst_frac: float = 0.1         # bursty: fraction of time in burst
    diurnal_period_s: float = 5e-3  # diurnal: envelope period (sim s)
    diurnal_peak: float = 1.8       # diurnal: peak/mean rate ratio

    def __post_init__(self):
        total = sum(getattr(self, k) for k in OP_KINDS)
        if abs(total - 1.0) > 1e-6:
            raise ValueError(
                f"workload {self.name!r}: op fractions sum to {total}, not 1")
        if self.distribution not in DISTRIBUTIONS:
            raise ValueError(
                f"workload {self.name!r}: unknown distribution "
                f"{self.distribution!r} (want one of {DISTRIBUTIONS})")
        if self.arrival not in ARRIVAL_KINDS:
            raise ValueError(
                f"workload {self.name!r}: unknown arrival process "
                f"{self.arrival!r} (want one of {ARRIVAL_KINDS})")
        if self.arrival != "closed" and self.offered_mops <= 0:
            raise ValueError(
                f"workload {self.name!r}: open-loop arrival "
                f"{self.arrival!r} needs offered_mops > 0")
        if self.arrival == "bursty":
            if not 0.0 < self.burst_frac < 1.0 or self.burst_factor <= 1.0 \
                    or self.burst_factor * self.burst_frac >= 1.0:
                raise ValueError(
                    f"workload {self.name!r}: bursty arrivals need "
                    f"0 < burst_frac < 1, burst_factor > 1 and "
                    f"burst_factor*burst_frac < 1 (got "
                    f"{self.burst_factor} x {self.burst_frac})")
        if self.arrival == "diurnal":
            if not 1.0 < self.diurnal_peak <= 2.0 or \
                    self.diurnal_period_s <= 0:
                raise ValueError(
                    f"workload {self.name!r}: diurnal arrivals need "
                    f"1 < peak <= 2 and period > 0 (got peak="
                    f"{self.diurnal_peak}, period={self.diurnal_period_s})")
        if self.distribution == "hotspot":
            if not 0.0 <= self.hot_frac <= 1.0 or self.hot_n < 1:
                raise ValueError(
                    f"workload {self.name!r}: hotspot needs "
                    f"0 <= hot_frac <= 1 and hot_n >= 1 (got "
                    f"{self.hot_frac}, {self.hot_n})")
        for ev in self.faults:
            if not isinstance(ev, FaultEvent):
                raise ValueError(
                    f"workload {self.name!r}: faults must be FaultEvent "
                    f"instances, got {type(ev).__name__}")
        object.__setattr__(self, "faults", tuple(self.faults))

    def replace(self, **kw) -> "WorkloadSpec":
        return dataclasses.replace(self, **kw)

    def fractions(self) -> dict:
        return {k: getattr(self, k) for k in OP_KINDS}

    #: Golden-ratio conjugate: ``frac(s * GOLDEN)`` is a low-discrepancy
    #: sequence, so remainder slots sample the op mix *in proportion to
    #: the fractions* while staying deterministic and well-interleaved.
    _GOLDEN = 0.6180339887498949

    def batch_counts(self, b: int, salt: int = 0) -> dict:
        """Deterministic per-batch op counts: floor each fraction, then
        assign each remainder slot by a fraction-weighted low-discrepancy
        draw (golden-ratio sequence over the cumulative mix).

        ``salt`` advances the sequence — the cluster scheduler passes
        ``round * n_cs + cs`` so that tiny per-CS batches (down to one
        lane) still realize the *weighted* mix over rounds (a 95/5 mix
        stays 95/5, not 50/50) instead of collapsing onto one kind,
        while shapes stay drawn from a bounded set (stable jit cache).
        """
        fracs = [(k, getattr(self, k)) for k in OP_KINDS]
        counts = {k: int(f * b) for k, f in fracs}
        rem = b - sum(counts.values())
        eligible = [(k, f) for k, f in sorted(fracs, key=lambda kv: -kv[1])
                    if f > 0]
        total = sum(f for _, f in eligible)
        for i in range(rem):
            u = ((salt + i + 1) * self._GOLDEN) % 1.0
            acc = 0.0
            for k, f in eligible:
                acc += f / total
                if u < acc or (k, f) == eligible[-1]:
                    counts[k] += 1
                    break
        return counts

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _s(name: str, **kw) -> WorkloadSpec:
    return WorkloadSpec(name=name, **kw)


#: The six standard YCSB core workloads (A-F).
YCSB_PRESETS = {
    "ycsb-a": _s("ycsb-a", read=0.5, update=0.5),
    "ycsb-b": _s("ycsb-b", read=0.95, update=0.05),
    "ycsb-c": _s("ycsb-c", read=1.0),
    "ycsb-d": _s("ycsb-d", read=0.95, insert=0.05, distribution="latest"),
    "ycsb-e": _s("ycsb-e", scan=0.95, insert=0.05),
    "ycsb-f": _s("ycsb-f", read=0.5, rmw=0.5),
}

#: Sherman's Table 3 mixes (§5).  Writes are *updates of live records* so
#: that skew produces the hot-leaf contention the paper measures.
TABLE3_PRESETS = {
    "write-only": _s("write-only", update=1.0),
    "write-intensive": _s("write-intensive", read=0.5, update=0.5),
    "read-intensive": _s("read-intensive", read=0.95, update=0.05),
    "range-only": _s("range-only", scan=1.0),
    "range-write": _s("range-write", scan=0.5, update=0.5),
}

PRESETS = {**YCSB_PRESETS, **TABLE3_PRESETS}


def get_preset(name: str, **overrides) -> WorkloadSpec:
    """Look up a named workload, optionally overriding fields
    (``get_preset("ycsb-a", theta=0.7, ops=1024)``)."""
    try:
        spec = PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown workload preset {name!r}; "
                       f"known: {', '.join(sorted(PRESETS))}") from None
    return spec.replace(**overrides) if overrides else spec
