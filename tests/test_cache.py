"""The functional CS-side index cache (repro.core.cache, paper §4.2.3):
hit/miss/stale accounting, eviction at the byte budget, versioned
invalidation, stale-traversal correctness against the oracle, and the
Pallas leaf-search kernel on the cached hot path."""
import numpy as np
import pytest

from repro.core import OracleIndex, ShermanIndex, TreeConfig
from repro.core.cache import (IndexCache, cached_lookup, descend_image,
                              fill_image)
from repro.workloads import SYSTEMS, build_index, get_preset, run_workload, \
    scramble

CFG = TreeConfig(n_ms=2, nodes_per_ms=2048, fanout=16, n_locks_per_ms=1024,
                 max_height=7, n_cs=4)
KEYSPACE = 1 << 20


def _fresh(records=4_000, **kw):
    return build_index(SYSTEMS["sherman"], CFG, records=records, **kw)


def _ranks(lo, hi):
    return scramble(np.arange(lo, hi), KEYSPACE).astype(np.int32)


# -- hit path --------------------------------------------------------------

def test_read_only_hits_and_single_remote_read():
    """Read-only (YCSB-C shape): every lookup is a cache hit costing one
    remote leaf read — the paper's single-round-trip fast path."""
    idx = _fresh()
    q = _ranks(0, 1_024)
    got, found = idx.lookup(q)
    assert found.all()
    c = idx.counters
    assert c["cache_hits"] == 1_024 and c["cache_misses"] == 0
    assert c["cache_stale"] == 0
    assert c["lookup_reads"] == c["lookup_ops"] == 1_024   # exactly 1 read/op
    assert idx.cache.hit_ratio == 1.0


def test_ycsb_c_acceptance_hit_rate():
    """The acceptance bar: YCSB-C at the default cache size reports >= 90%
    hit rate and ~1 remote read per lookup."""
    spec = get_preset("ycsb-c", load_records=4_000, ops=1_024, batch=512)
    idx = _fresh()
    r = run_workload(idx, spec, system="sherman")
    assert r.cache_hit_rate >= 0.9
    assert r.reads_per_lookup == pytest.approx(1.0, abs=0.1)
    assert r.cache_hits + r.cache_misses + r.cache_stale == r.n_ops


def test_disabled_cache_pays_full_traversals():
    idx = _fresh(cache_bytes=0)
    q = _ranks(0, 256)
    _, found = idx.lookup(q)
    assert found.all()
    c = idx.counters
    assert c["cache_hits"] == 0 and c["cache_misses"] == 256
    height = int(idx.state.height)
    assert c["lookup_reads"] == 256 * height


def test_partial_cache_levels_price_partial_descent():
    """With only the top 2 levels cached, a lookup resumes remotely from
    the first uncached level: reads = height - cached depth, not a full
    traversal."""
    idx = _fresh(records=8_000, cache_levels=2)
    q = _ranks(0, 256)
    _, found = idx.lookup(q)
    assert found.all()
    c = idx.counters
    assert c["cache_hits"] == 0 and c["cache_misses"] == 256
    height = int(idx.state.height)
    assert height > 3                  # deep enough for a partial descent
    assert c["lookup_reads"] == 256 * (height - 2)


# -- stale path ------------------------------------------------------------

def test_stale_cache_lookups_match_oracle():
    """Inserts/splits after the cache fill leave the image stale; lookups
    must still be oracle-correct via the B-link chase, with stale > 0."""
    idx = _fresh(records=2_000)
    oracle = OracleIndex()
    rng = np.random.default_rng(3)
    load_k = _ranks(0, 2_000)
    # overwrite the load-phase values with known ones so the oracle agrees
    load_v = rng.integers(0, 1 << 20, 2_000).astype(np.int32)
    idx.insert(load_k, load_v)
    oracle.insert_batch(load_k, load_v)

    idx.lookup(load_k[:64])                     # warm fill, pre-split image
    fills_before = idx.cache.counters.fills
    stale_seen = 0
    for lo in range(2_000, 2_500, 100):         # interleave inserts + reads
        new_k = _ranks(lo, lo + 100)
        new_v = rng.integers(0, 1 << 20, 100).astype(np.int32)
        idx.insert(new_k, new_v)
        oracle.insert_batch(new_k, new_v)
        probe = np.concatenate([new_k, load_k[rng.integers(0, 2_000, 156)]])
        got, found = idx.lookup(probe)
        assert found.all()
        want = np.asarray([oracle.lookup(int(k)) for k in probe])
        np.testing.assert_array_equal(got, want)
        stale_seen = idx.counters["cache_stale"]
    assert idx.counters["leaf_splits"] > 0
    # the stale path ran unless every split batch forced a refresh
    assert stale_seen > 0 or idx.cache.counters.fills > fills_before


def test_random_op_mix_with_stale_cache_matches_oracle():
    """Seeded pseudo-property test (no hypothesis needed): arbitrary
    insert/delete/lookup interleavings against a deliberately
    never-refreshed cache still return oracle-correct results."""
    idx = _fresh(records=1_000)
    idx.cache.sync_every = 0            # never version-sync
    idx.cache.refresh_frac = 1.1        # never refresh on invalid fraction
    oracle = OracleIndex()
    k0 = _ranks(0, 1_000)
    v0 = np.arange(1_000, dtype=np.int32)
    idx.insert(k0, v0)
    oracle.insert_batch(k0, v0)
    idx.lookup(k0[:32])                 # warm fill
    rng = np.random.default_rng(11)
    cursor = 1_000
    for _ in range(6):
        ins = _ranks(cursor, cursor + 150)
        cursor += 150
        vals = rng.integers(0, 1 << 20, 150).astype(np.int32)
        idx.insert(ins, vals)
        oracle.insert_batch(ins, vals)
        dele = scramble(rng.choice(cursor, 40, replace=False),
                        KEYSPACE).astype(np.int32)
        idx.delete(dele)
        oracle.delete_batch(dele)
        probe = np.concatenate(
            [ins[:50], _ranks(0, cursor)[rng.integers(0, cursor, 100)]])
        got, found = idx.lookup(probe)
        want = [oracle.lookup(int(k)) for k in probe]
        for g, f, w in zip(got, found, want):
            if w is None:
                assert not f
            else:
                assert f and g == w
    assert idx.counters["leaf_splits"] > 0
    assert idx.counters["cache_stale"] > 0      # stale path was exercised


def test_empty_batches_are_noops():
    idx = _fresh(records=2_000)
    got, found = idx.lookup(np.zeros(0, np.int32))
    assert got.size == 0 and found.size == 0
    rk, rv, rn = idx.range(np.zeros(0, np.int32), count=4)
    assert rn.size == 0


def test_lazy_invalidation_targets_covering_entry_once():
    """Repeated stale detections for one key region invalidate the covering
    level-1 entry exactly once — never its (still-correct) neighbors."""
    idx = _fresh(records=4_000)
    idx.lookup(_ranks(0, 32))                   # fill the image
    k = _ranks(100, 101)
    valid_before = idx.cache._valid.sum()
    assert idx.cache.invalidate_covering(k) == 1
    assert idx.cache.invalidate_covering(k) == 0     # no-op, not a neighbor
    assert idx.cache._valid.sum() == valid_before - 1


def test_upper_level_invalidation_forces_refresh():
    """Losing a cached root/upper-level row would cut off every descent;
    the cache must refresh instead of limping at full-miss pricing until
    the bulk invalid-fraction threshold trips."""
    idx = _fresh(records=4_000)
    idx.lookup(_ranks(0, 32))                   # fill
    cache = idx.cache
    v = cache._valid.copy()
    v[cache._rows == cache._root] = False       # as a version sweep would
    cache._set_valid(v)
    fills0 = cache.counters.fills
    misses0 = idx.counters["cache_misses"]
    _, found = idx.lookup(_ranks(0, 64))
    assert found.all()
    assert cache.counters.fills > fills0        # refreshed, not degraded
    assert idx.counters["cache_misses"] == misses0


def test_ops_lookup_batch_consults_cache():
    """ops.lookup_batch with a cache image matches the plain traversal and
    reports the single-remote-read hop count."""
    import jax.numpy as jnp
    from repro.core.ops import lookup_batch
    idx = _fresh(records=3_000)
    img, _ = fill_image(CFG, idx.state)
    q = jnp.asarray(_ranks(0, 128))
    r_c = lookup_batch(CFG, idx.state, q, cache_image=img)
    r_p = lookup_batch(CFG, idx.state, q)
    np.testing.assert_array_equal(np.asarray(r_c.value),
                                  np.asarray(r_p.value))
    np.testing.assert_array_equal(np.asarray(r_c.found),
                                  np.asarray(r_p.found))
    assert (np.asarray(r_c.hops) == 1).all()    # fresh image: 1 remote read


def test_range_start_descent_consults_cache():
    idx = _fresh(records=3_000)
    idx.range(_ranks(0, 32), count=8)
    assert idx.cache.counters.hits >= 32        # start descents hit


def test_cache_maintenance_is_priced():
    """Image fills and version sweeps show up as netsim messages/bytes."""
    idx = _fresh(records=2_000)
    idx.lookup(_ranks(0, 16))                   # triggers the first fill
    assert idx.cache.counters.fill_reads > 0
    assert idx.counters["msgs"] > idx.counters["lookup_reads"]


# -- eviction / budget -----------------------------------------------------

def test_eviction_at_byte_budget():
    """A cache smaller than the internal levels keeps the top levels,
    evicts level-1 nodes, stays under budget, and still answers
    correctly (misses pay full traversals)."""
    budget = 6 * CFG.node_bytes
    idx = _fresh(records=8_000, cache_bytes=budget)
    q = _ranks(0, 512)
    got, found = idx.lookup(q)
    assert found.all()
    cc = idx.cache.counters
    assert cc.evictions > 0
    assert idx.cache.cached_bytes <= budget
    assert idx.counters["cache_misses"] > 0
    # the kept rows are the *top* levels (never a dropped root)
    img = idx.cache._image
    lvl = np.asarray(img["level"])[np.asarray(img["valid"])]
    assert int(np.asarray(idx.state.level)[int(idx.state.root)]) in lvl


def test_counter_accounting_identity():
    """hits + misses + stale == lookups issued; remote reads are >= 1 per
    lookup and exactly 1 for clean hits."""
    idx = _fresh(records=4_000)
    q = _ranks(0, 700)
    idx.lookup(q)
    idx.insert(_ranks(4_000, 4_600),
               np.arange(600, dtype=np.int32))
    idx.lookup(q)
    c = idx.counters
    assert c["cache_hits"] + c["cache_misses"] + c["cache_stale"] \
        == c["lookup_ops"] == 1_400
    assert c["lookup_reads"] >= c["lookup_ops"]


# -- versioned invalidation ------------------------------------------------

def test_version_sync_invalidates_changed_nodes():
    idx = _fresh(records=2_000)
    idx.cache.sync_every = 10**9        # isolate: no automatic sweeps
    idx.lookup(_ranks(0, 64))           # fill
    before = idx.cache.counters.invalidations
    idx.insert(_ranks(2_000, 2_800), np.arange(800, dtype=np.int32))
    assert idx.counters["leaf_splits"] > 0
    n = idx.cache.sync_versions(idx.state)
    # separator inserts bumped parent FNVs => entries must invalidate,
    # unless a root split already forced a full refresh
    assert n > 0 or idx.cache.counters.fills > 1 or \
        idx.cache._needs_refresh
    assert idx.cache.counters.sync_sweeps >= 1
    assert idx.cache.counters.invalidations >= before
    # lookups after the sweep remain correct
    _, found = idx.lookup(_ranks(0, 256))
    assert found.all()


def test_root_split_forces_refresh():
    cfg = TreeConfig(n_ms=2, nodes_per_ms=1024, fanout=4,
                     n_locks_per_ms=512, max_height=7, n_cs=2)
    rng = np.random.default_rng(5)
    keys = np.sort(rng.choice(50_000, 40, replace=False)).astype(np.int32)
    idx = ShermanIndex.build(cfg, keys, np.arange(40, dtype=np.int32))
    idx.lookup(keys[:8])
    fills0 = idx.cache.counters.fills
    extra = np.setdiff1d(np.arange(50_000, dtype=np.int32), keys)
    extra = rng.permutation(extra)[:400].astype(np.int32)
    idx.insert(extra, np.arange(400, dtype=np.int32))
    assert idx.counters["root_splits"] > 0
    _, found = idx.lookup(keys)
    assert found.all()
    assert idx.cache.counters.fills > fills0      # image was rebuilt


# -- kernel parity ---------------------------------------------------------

def test_cached_lookup_kernel_parity():
    """The Pallas leaf-search kernel (interpret mode) and the jnp reference
    agree on the cached hot path, including non-tile-aligned batches."""
    import jax.numpy as jnp
    idx = _fresh(records=3_000)
    img, _ = fill_image(CFG, idx.state)
    for n in (100, 256, 300):
        q = jnp.asarray(_ranks(0, n))
        r_ref, s_ref = cached_lookup(CFG, idx.state, img, q,
                                     kernel_mode="ref")
        r_pal, s_pal = cached_lookup(CFG, idx.state, img, q,
                                     kernel_mode="interpret")
        np.testing.assert_array_equal(np.asarray(r_ref.value),
                                      np.asarray(r_pal.value))
        np.testing.assert_array_equal(np.asarray(r_ref.found),
                                      np.asarray(r_pal.found))
        np.testing.assert_array_equal(np.asarray(s_ref.remote_reads),
                                      np.asarray(s_pal.remote_reads))


def test_descend_image_routes_like_traverse():
    """Cache descent lands on the same leaf as the real traversal when the
    image is fresh."""
    import jax.numpy as jnp
    from repro.core.ops import traverse
    idx = _fresh(records=3_000)
    img, _ = fill_image(CFG, idx.state)
    q = jnp.asarray(_ranks(0, 512))
    leaf, hit, depth = descend_image(img, q, CFG.max_height)
    assert np.asarray(hit).all()
    # hits descended through every internal level locally
    assert (np.asarray(depth) == int(idx.state.height) - 1).all()
    tr = traverse(CFG, idx.state, q)
    np.testing.assert_array_equal(np.asarray(leaf), np.asarray(tr.leaf))


# -- hypothesis property test (skipped when hypothesis is absent) ----------

def test_property_stale_cache_oracle():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    cfg = TreeConfig(n_ms=2, nodes_per_ms=1024, fanout=8,
                     n_locks_per_ms=512, max_height=7, n_cs=2)
    KEYS = st.integers(min_value=0, max_value=2_000)
    VALS = st.integers(min_value=0, max_value=1 << 20)
    batch = st.lists(st.tuples(KEYS, VALS), min_size=1, max_size=32)

    @settings(max_examples=15, deadline=None)
    @given(st.lists(batch, min_size=1, max_size=5))
    def inner(batches):
        idx = ShermanIndex.empty(cfg)
        idx.cache.sync_every = 0
        idx.cache.refresh_frac = 1.1
        oracle = OracleIndex()
        seed_k = np.arange(0, 2_000, 7, dtype=np.int32)
        idx.insert(seed_k, seed_k)
        oracle.insert_batch(seed_k, seed_k)
        idx.lookup(seed_k[:16])             # warm the image
        for b in batches:
            ks = np.asarray([k for k, _ in b], np.int32)
            vs = np.asarray([v for _, v in b], np.int32)
            idx.insert(ks, vs)
            oracle.insert_batch(ks.tolist(), vs.tolist())
            probe = np.unique(np.concatenate([ks, seed_k[:64]]))
            got, found = idx.lookup(probe)
            for k, g, f in zip(probe, got, found):
                w = oracle.lookup(int(k))
                assert (w is None and not f) or (f and g == w), (k, g, w)

    inner()
