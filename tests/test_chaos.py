"""Chaos plane (DESIGN.md §13): the differential recovery harness.

Three guarantees, asserted for both systems and all fault kinds:

1. **Differential correctness** — every faulted run converges to exactly
   the tree contents of the fault-free oracle (an ``OracleIndex`` replay
   of the *executed* write log); an MS crash without memory loss is
   bit-identical to the un-faulted run.
2. **Conservation across crash boundaries** — merged-timeline verb /
   doorbell / byte totals still equal the per-CS functional sums after
   abandon-and-re-derive or restore-and-replay recovery.
3. **Tick-for-tick resume** — a fresh runner restored from a mid-run
   checkpoint continues with *identical merged-trace digests* to the
   uninterrupted run.

Plus seeded + hypothesis properties over random fault schedules.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.chaos import (ChaosRunner, oracle_replay, recovery_trace,
                         requeue_repairs, schedule_for_horizon,
                         tree_contents)
from repro.chaos import faults as chaos_faults
from repro.cluster import build_cluster, run_cluster
from repro.cluster.sched import VAL_MASK
from repro.core.netsim import FG_PLUS, SHERMAN
from repro.core.tree import TreeConfig
from repro.workloads.keygen import scramble
from repro.workloads.spec import FaultEvent, WorkloadSpec

pytestmark = pytest.mark.chaos

CFG = TreeConfig(n_ms=2, nodes_per_ms=1024, fanout=8, n_locks_per_ms=512,
                 max_height=6, n_cs=4)
RECORDS = 2_000
MIX = WorkloadSpec(name="chaos-mix", read=0.3, update=0.3, insert=0.2,
                   delete=0.1, rmw=0.1, load_records=RECORDS, ops=640,
                   batch=128)
SYSTEMS = {"sherman": SHERMAN, "fg+": FG_PLUS}


def _build(feat):
    return build_cluster(feat, CFG, n_clients=8, records=RECORDS,
                         cache_bytes=4 << 20, sync_rounds=2)


def _loaded():
    """The exact bulk-load records build_cluster used (seed 0)."""
    rng = np.random.default_rng(0)
    keys = scramble(np.arange(RECORDS, dtype=np.int64), 1 << 20)
    return keys, rng.integers(0, VAL_MASK, size=RECORDS)


def _assert_oracle(runner):
    got = tree_contents(runner.cluster.state)
    want = dict(oracle_replay(*_loaded(), runner.write_log).items())
    assert got == want
    assert runner.cluster.conservation_ok()


@pytest.fixture(scope="module")
def baseline():
    """Fault-free reference per system: digests, contents, horizon."""
    out = {}
    for name, feat in SYSTEMS.items():
        cl = _build(feat)
        cl.record_traces()
        run_cluster(cl, MIX, seed=1)
        out[name] = cl
    return out


# --------------------------------------------------------------------------
# the runner is a faithful run_cluster when nothing fails
# --------------------------------------------------------------------------

def test_empty_schedule_matches_run_cluster(baseline):
    """Same draws, same waves, same merged traces: the chaos runner with
    no faults is run_cluster, digest for digest."""
    cl = _build(SHERMAN)
    cl.record_traces()
    r = ChaosRunner(cl, MIX, seed=1).run()
    ref = baseline["sherman"]
    assert cl.trace_log == ref.trace_log
    assert tree_contents(cl.state) == tree_contents(ref.state)
    assert r.done == MIX.ops
    _assert_oracle(r)


# --------------------------------------------------------------------------
# MS crash: on-chip loss, downtime, re-derivation, full memory loss
# --------------------------------------------------------------------------

@pytest.mark.parametrize("system", sorted(SYSTEMS))
def test_ms_crash_bit_identical(baseline, system):
    """Crash + GLT loss + repair abandonment with surviving DRAM must
    converge to the *bit-identical* final tree of the un-faulted run:
    the GLT is quiescent between waves and re-derived repairs complete
    the same half-splits."""
    ref = baseline[system]
    h = ref.counters["sim_time_s"]
    spec = MIX.replace(faults=(
        FaultEvent("ms_crash", at_s=0.3 * h, ms=0, down_s=0.02 * h),
        FaultEvent("ms_crash", at_s=0.6 * h, ms=1, down_s=0.01 * h),
    ))
    r = ChaosRunner(_build(SYSTEMS[system]), spec, seed=1).run()
    crashes = [f for f in r.fault_log if f["kind"] == "ms_crash"]
    assert len(crashes) == 2
    for st_ref, st in zip(ref.state, r.cluster.state):
        np.testing.assert_array_equal(np.asarray(st_ref), np.asarray(st))
    assert (np.asarray(r.cluster.state.glt) == 0).all()
    _assert_oracle(r)
    # downtime stalls the clock: the faulted run is strictly longer
    assert r.cluster.counters["sim_time_s"] > h


def test_ms_crash_lose_memory_replays(tmp_path, baseline):
    """Full memory loss: the tree image restores from the checkpoint and
    the redo log replays every wave since — same final contents, and the
    replay is visible in the fault log."""
    h = baseline["sherman"].counters["sim_time_s"]
    spec = MIX.replace(faults=(
        FaultEvent("ms_crash", at_s=0.55 * h, ms=1, down_s=0.03 * h,
                   lose_memory=True),))
    r = ChaosRunner(_build(SHERMAN), spec, seed=1,
                    ckpt_dir=str(tmp_path), ckpt_every=2).run()
    crash = [f for f in r.fault_log if f["kind"] == "ms_crash"]
    assert len(crash) == 1 and crash[0]["lose_memory"]
    assert crash[0]["replayed_waves"] >= 1
    assert tree_contents(r.cluster.state) == \
        tree_contents(baseline["sherman"].state)
    _assert_oracle(r)
    rep = r.report()
    row = [f for f in rep["faults"] if f["kind"] == "ms_crash"][0]
    assert row["ttr_s"] is not None and math.isfinite(row["ttr_s"])
    assert row["degraded_mops"] > 0


def test_ms_crash_lose_memory_needs_checkpoint():
    spec = MIX.replace(faults=(
        FaultEvent("ms_crash", at_s=0.0, ms=0, lose_memory=True),))
    with pytest.raises(RuntimeError, match="checkpoint"):
        ChaosRunner(_build(SHERMAN), spec, seed=1).run()


def test_crash_strands_and_rederives_repairs():
    """The mechanism itself: a wave run with drain=False leaves its
    half-splits pending; abandon + re-derive + drain completes them to
    the same tree a normally-drained twin reaches."""
    # a clustered key window: ~16 fresh keys per covered leaf, enough to
    # overflow and split many of them inside one wave
    keys = (500_000 + np.arange(192) * 200).astype(np.int32)

    def wave(cl, drain):
        kb = [keys[i::4] for i in range(4)]
        cl.write_wave(kb, kb, drain=drain)

    cl_ref = _build(SHERMAN)
    wave(cl_ref, drain=True)
    cl = _build(SHERMAN)
    wave(cl, drain=False)
    assert cl._repair_backlog > 0          # half-splits stranded in flight
    mirror = chaos_faults.abandon_repairs(cl)
    assert mirror is not None and mirror["valid"].sum() > 0
    assert cl._repair_backlog == 0         # queue abandoned, tree B-link-ok
    requeue_repairs(cl, mirror)
    cl.drain_repairs()
    for a, b in zip(cl_ref.state, cl.state):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_recovery_trace_shape():
    """Recovery traffic: background, independent, byte-conserving."""
    t = recovery_trace(CFG, 1, scan_rows=1000, small_bytes=64)
    assert (t.ms == 1).all() and (t.lane == -1).all()
    assert (t.dep == -1).all() and (t.doorbell == np.arange(t.n_verbs)).all()
    assert t.nbytes.sum() == CFG.n_locks_per_ms * 2 + 1000 * 64
    t2 = recovery_trace(CFG, 0, restore_rows=500)
    assert t2.nbytes.sum() == CFG.n_locks_per_ms * 2 + 500 * CFG.node_bytes
    assert t2.n_verbs <= 1 + chaos_faults.MAX_RECOVERY_VERBS


# --------------------------------------------------------------------------
# CS churn and skew storms
# --------------------------------------------------------------------------

@pytest.mark.parametrize("system", sorted(SYSTEMS))
def test_cs_leave_join_failover(baseline, system):
    """A dead CS's clients fail over (same op stream, new placement); a
    rejoining CS comes back cold.  The executed write log stays
    oracle-correct and conservation holds through the churn."""
    h = baseline[system].counters["sim_time_s"]
    spec = MIX.replace(faults=(
        FaultEvent("cs_leave", at_s=0.3 * h, cs=2),
        FaultEvent("cs_join", at_s=0.65 * h, cs=2),
    ))
    r = ChaosRunner(_build(SYSTEMS[system]), spec, seed=1).run()
    kinds = [f["kind"] for f in r.fault_log if not f.get("skipped")]
    assert kinds == ["cs_leave", "cs_join"]
    _assert_oracle(r)
    # while dead, slot 2's batches ran on other CSs: its op counter froze
    ops_by_cs = [n.counters["ops"] for n in r.cluster.nodes]
    ref_ops = [n.counters["ops"] for n in baseline[system].nodes]
    assert ops_by_cs[2] < ref_ops[2]
    assert sum(ops_by_cs) == sum(ref_ops)   # nothing lost, only moved


def test_cs_leave_never_kills_last(baseline):
    h = baseline["sherman"].counters["sim_time_s"]
    spec = MIX.replace(faults=tuple(
        FaultEvent("cs_leave", at_s=0.1 * h * (i + 1), cs=i)
        for i in range(4)))
    r = ChaosRunner(_build(SHERMAN), spec, seed=1).run()
    leaves = [f for f in r.fault_log if f["kind"] == "cs_leave"]
    assert sum(1 for f in leaves if f.get("skipped")) == 1
    assert sum(r.alive) == 1
    _assert_oracle(r)


def test_skew_shift_storm(baseline):
    """A hot-key storm (hotspot over 8 keys) and its lift both fire;
    draws stay deterministic (RNG call counts unchanged) so the run is
    still oracle-correct, and the storm leaves no residue: after the
    lift the stream spec is back to the original distribution."""
    h = baseline["sherman"].counters["sim_time_s"]
    spec = MIX.replace(faults=(
        FaultEvent("skew_shift", at_s=0.4 * h, distribution="hotspot",
                   hot_frac=0.95, hot_n=8),
        FaultEvent("skew_shift", at_s=0.75 * h, distribution="zipfian",
                   theta=0.99),
    ))
    r = ChaosRunner(_build(SHERMAN), spec, seed=1).run()
    shifts = [f for f in r.fault_log if f["kind"] == "skew_shift"]
    assert [s["distribution"] for s in shifts] == ["hotspot", "zipfian"]
    assert r.streams.spec.distribution == "zipfian"
    _assert_oracle(r)


# --------------------------------------------------------------------------
# checkpoint / resume: tick-for-tick
# --------------------------------------------------------------------------

def _runner(tmp, tag, spec, record=True, every=3):
    cl = _build(SHERMAN)
    if record:
        cl.record_traces()
    return ChaosRunner(cl, spec, seed=1, ckpt_dir=f"{tmp}/{tag}",
                       ckpt_every=every)


def test_checkpoint_resume_tick_for_tick(tmp_path, baseline):
    """A fresh runner restored from the round-3 snapshot continues with
    merged-trace digests equal to the uninterrupted run's tail — the
    strongest no-divergence statement the performance plane can make."""
    ra = _runner(tmp_path, "a", MIX).run()
    rb = _runner(tmp_path, "b", MIX)
    rb.run(until_round=3)
    n_dig = len(rb.cluster.trace_log)
    rb2 = _runner(tmp_path, "b", MIX)          # fresh build, same recipe
    assert rb2.load_latest() == 3
    rb2.cluster.record_traces()
    rb2.run()
    assert rb2.cluster.trace_log == ra.cluster.trace_log[n_dig:]
    assert rb2.cluster.counters["sim_time_s"] == \
        ra.cluster.counters["sim_time_s"]
    assert rb2.done == ra.done
    for a, b in zip(ra.cluster.state, rb2.cluster.state):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_resume_across_fault(tmp_path, baseline):
    """Resume before a memory-losing crash: the resumed run and the
    uninterrupted run see the same crash, replay the same redo log, and
    land on the same final state and horizon."""
    h = baseline["sherman"].counters["sim_time_s"]
    spec = MIX.replace(faults=(
        FaultEvent("ms_crash", at_s=0.7 * h, ms=0, down_s=0.01 * h,
                   lose_memory=True),))
    ra = _runner(tmp_path, "a", spec, record=False).run()
    rb = _runner(tmp_path, "b", spec, record=False)
    rb.run(until_round=3)
    rb2 = _runner(tmp_path, "b", spec, record=False)
    rb2.load_latest()
    rb2.run()
    assert tree_contents(ra.cluster.state) == \
        tree_contents(rb2.cluster.state)
    assert ra.cluster.counters["sim_time_s"] == \
        rb2.cluster.counters["sim_time_s"]
    assert [f["kind"] for f in rb2.fault_log] == \
        [f["kind"] for f in ra.fault_log]


# --------------------------------------------------------------------------
# properties: random schedules never break the invariants
# --------------------------------------------------------------------------

def test_standard_schedule_covers_kinds():
    sched = schedule_for_horizon(1.0)
    kinds = {ev.kind for ev in sched}
    assert kinds == {"ms_crash", "cs_leave", "cs_join", "skew_shift"}
    assert list(sched) == sorted(sched, key=lambda e: e.at_s)
    assert all(0 <= ev.at_s < 1.0 for ev in sched)
    # declarative surface round-trips through the spec
    spec = MIX.replace(faults=sched)
    assert [dataclasses.asdict(f) for f in spec.faults] == \
        [dataclasses.asdict(f) for f in sched]


@pytest.mark.slow
def test_property_random_schedules(tmp_path, baseline):
    """Hypothesis sweep: any schedule of crashes / churn / skew shifts
    keeps the differential and conservation invariants."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    h = baseline["sherman"].counters["sim_time_s"]
    event = st.one_of(
        st.builds(FaultEvent, st.just("ms_crash"),
                  at_s=st.floats(0, h, allow_nan=False),
                  ms=st.integers(0, CFG.n_ms - 1),
                  down_s=st.floats(0, 0.05 * h, allow_nan=False),
                  lose_memory=st.booleans()),
        st.builds(FaultEvent, st.just("cs_leave"),
                  at_s=st.floats(0, h, allow_nan=False),
                  cs=st.integers(0, CFG.n_cs - 1)),
        st.builds(FaultEvent, st.just("cs_join"),
                  at_s=st.floats(0, h, allow_nan=False),
                  cs=st.integers(0, CFG.n_cs - 1)),
        st.builds(FaultEvent, st.just("skew_shift"),
                  at_s=st.floats(0, h, allow_nan=False),
                  distribution=st.sampled_from(
                      ("uniform", "hotspot", "zipfian")),
                  theta=st.floats(0.5, 0.99), hot_n=st.integers(4, 64)))

    import tempfile

    @settings(max_examples=6, deadline=None)
    @given(st.lists(event, min_size=1, max_size=5),
           st.integers(0, 2 ** 31 - 1))
    def inner(faults, seed):
        spec = MIX.replace(ops=384, faults=tuple(faults))
        # one fresh checkpoint dir per example: a stale snapshot from a
        # different schedule must never be restorable
        ckpt = tempfile.mkdtemp(dir=tmp_path)
        r = ChaosRunner(_build(SHERMAN), spec, seed=1,
                        ckpt_dir=ckpt, ckpt_every=2).run()
        _assert_oracle(r)
        assert (np.asarray(r.cluster.state.glt) == 0).all()
        rep = r.report()
        assert rep["conservation_ok"]

    inner()
