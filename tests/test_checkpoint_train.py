"""Checkpoint manager + fault-tolerant training loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_reduced
from repro.launch.train import StragglerWatchdog, TrainConfig, run
from repro.models.registry import build
from repro.optim import adamw


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": (jnp.ones(4), jnp.zeros(()))}
    mgr.save(tree, step=3)
    out = mgr.restore(tree, 3)
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert (np.asarray(x) == np.asarray(y)).all()


def test_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        mgr.save({"x": jnp.full(2, s)}, step=s)
    assert mgr.steps() == [3, 4]
    (restored, step) = mgr.restore_latest(tree)
    assert step == 4 and (np.asarray(restored["x"]) == 4).all()


def test_restore_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save({"x": jnp.zeros(3)}, step=1)
    with pytest.raises(ValueError):
        mgr.restore({"x": jnp.zeros(4)}, 1)


def test_atomicity_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save({"x": jnp.zeros(2)}, step=1)
    assert not any(d.startswith(".tmp") for d in os.listdir(tmp_path))


# -- corruption injection: every leaf is validated against the manifest ----
# (the chaos plane's crash-recovery path restores from these files; a
# corrupt leaf must fail loudly, never load silently)

def _leaf_path(tmp_path, step, name="leaf_00000"):
    return os.path.join(tmp_path, f"step_{step:08d}", name + ".npy")


def test_restore_rejects_swapped_dtype(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.arange(8, dtype=jnp.int32)}
    mgr.save(tree, step=1)
    np.save(_leaf_path(tmp_path, 1), np.arange(8, dtype=np.float64))
    with pytest.raises(ValueError, match="dtype"):
        mgr.restore(tree, 1)
    with pytest.raises(ValueError, match="dtype"):
        mgr.restore_raw(1)


def test_restore_rejects_resized_leaf(tmp_path):
    """Same dtype, wrong shape — e.g. a stale leaf from an older run with
    a different pool geometry."""
    mgr = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.zeros((4, 3), jnp.float32)}
    mgr.save(tree, step=2)
    np.save(_leaf_path(tmp_path, 2), np.zeros((4, 7), np.float32))
    with pytest.raises(ValueError, match="shape"):
        mgr.restore(tree, 2)


def test_restore_rejects_truncated_npy(tmp_path):
    """A crash mid-write leaves a torn file: unreadable, not mis-loaded."""
    mgr = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.arange(1024)}
    mgr.save(tree, step=3)
    path = _leaf_path(tmp_path, 3)
    blob = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(blob[:len(blob) // 3])
    with pytest.raises(ValueError, match="unreadable|shape|dtype"):
        mgr.restore(tree, 3)


def test_restore_latest_skips_nothing_validates_everything(tmp_path):
    """restore_latest goes through the same validated path."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"x": jnp.zeros(5)}
    mgr.save({"x": jnp.ones(5)}, step=1)
    mgr.save({"x": jnp.full(5, 2.0)}, step=2)
    np.save(_leaf_path(tmp_path, 2), np.zeros(5, np.int8))
    with pytest.raises(ValueError):
        mgr.restore_latest(tree)


def test_train_loop_and_resume(tmp_path):
    cfg = get_reduced("smollm_135m")
    api = build(cfg)
    tc = TrainConfig(steps=6, ckpt_every=3, log_every=100,
                     ckpt_dir=str(tmp_path),
                     opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=1,
                                           total_steps=6))
    out = run(api, tc, batch_size=2, seq=16, verbose=False)
    assert len(out["losses"]) == 6
    assert np.isfinite(out["losses"]).all()
    # resume: a second run should pick up from the saved step (6)
    tc2 = TrainConfig(steps=8, ckpt_every=4, log_every=100,
                      ckpt_dir=str(tmp_path),
                      opt=tc.opt)
    out2 = run(api, tc2, batch_size=2, seq=16, verbose=False)
    assert len(out2["losses"]) == 2       # only steps 6, 7 executed


def test_training_reduces_loss():
    cfg = get_reduced("smollm_135m")
    api = build(cfg)
    tc = TrainConfig(steps=30, ckpt_every=10_000, log_every=1000,
                     ckpt_dir="/tmp/_nockpt_test",
                     opt=adamw.AdamWConfig(lr=3e-3, warmup_steps=2,
                                           total_steps=30))
    import shutil
    shutil.rmtree("/tmp/_nockpt_test", ignore_errors=True)
    out = run(api, tc, batch_size=4, seq=32, verbose=False)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first, (first, last)


def test_straggler_watchdog():
    dog = StragglerWatchdog(factor=3.0)
    for _ in range(10):
        assert not dog.observe(0.1)
    assert dog.observe(1.0)
    assert dog.flagged == 1


def test_grad_compression_int8_close():
    from repro.optim.compression import compress_grads
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .standard_normal((64, 64)), jnp.float32)}
    gq = compress_grads(g, "int8")
    err = float(jnp.max(jnp.abs(g["w"] - gq["w"])))
    assert err < float(jnp.max(jnp.abs(g["w"]))) / 100
