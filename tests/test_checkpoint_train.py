"""Checkpoint manager + fault-tolerant training loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_reduced
from repro.launch.train import StragglerWatchdog, TrainConfig, run
from repro.models.registry import build
from repro.optim import adamw


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": (jnp.ones(4), jnp.zeros(()))}
    mgr.save(tree, step=3)
    out = mgr.restore(tree, 3)
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert (np.asarray(x) == np.asarray(y)).all()


def test_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        mgr.save({"x": jnp.full(2, s)}, step=s)
    assert mgr.steps() == [3, 4]
    (restored, step) = mgr.restore_latest(tree)
    assert step == 4 and (np.asarray(restored["x"]) == 4).all()


def test_restore_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save({"x": jnp.zeros(3)}, step=1)
    with pytest.raises(ValueError):
        mgr.restore({"x": jnp.zeros(4)}, 1)


def test_atomicity_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save({"x": jnp.zeros(2)}, step=1)
    assert not any(d.startswith(".tmp") for d in os.listdir(tmp_path))


def test_train_loop_and_resume(tmp_path):
    cfg = get_reduced("smollm_135m")
    api = build(cfg)
    tc = TrainConfig(steps=6, ckpt_every=3, log_every=100,
                     ckpt_dir=str(tmp_path),
                     opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=1,
                                           total_steps=6))
    out = run(api, tc, batch_size=2, seq=16, verbose=False)
    assert len(out["losses"]) == 6
    assert np.isfinite(out["losses"]).all()
    # resume: a second run should pick up from the saved step (6)
    tc2 = TrainConfig(steps=8, ckpt_every=4, log_every=100,
                      ckpt_dir=str(tmp_path),
                      opt=tc.opt)
    out2 = run(api, tc2, batch_size=2, seq=16, verbose=False)
    assert len(out2["losses"]) == 2       # only steps 6, 7 executed


def test_training_reduces_loss():
    cfg = get_reduced("smollm_135m")
    api = build(cfg)
    tc = TrainConfig(steps=30, ckpt_every=10_000, log_every=1000,
                     ckpt_dir="/tmp/_nockpt_test",
                     opt=adamw.AdamWConfig(lr=3e-3, warmup_steps=2,
                                           total_steps=30))
    import shutil
    shutil.rmtree("/tmp/_nockpt_test", ignore_errors=True)
    out = run(api, tc, batch_size=4, seq=32, verbose=False)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first, (first, last)


def test_straggler_watchdog():
    dog = StragglerWatchdog(factor=3.0)
    for _ in range(10):
        assert not dog.observe(0.1)
    assert dog.observe(1.0)
    assert dog.flagged == 1


def test_grad_compression_int8_close():
    from repro.optim.compression import compress_grads
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .standard_normal((64, 64)), jnp.float32)}
    gq = compress_grads(g, "int8")
    err = float(jnp.max(jnp.abs(g["w"] - gq["w"])))
    assert err < float(jnp.max(jnp.abs(g["w"]))) / 100
