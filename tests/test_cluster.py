"""Multi-CS cluster plane (DESIGN.md §11): functional correctness of the
fleet against the oracle, *lazy* cross-CS cache coherence, merged-trace
conservation (seeded + hypothesis), cross-CS GLT serialization in the
event loop, and the client-scaling acceptance curve."""
import json

import numpy as np
import jax.numpy as jnp
import pytest

from repro.cluster import ClusterStreams, build_cluster, run_cluster
from repro.core import netsim, verbs as V, write
from repro.core.api import write_stats_dict
from repro.core.netsim import FG_PLUS, SHERMAN, NetConfig
from repro.core.ref import OracleIndex
from repro.core.tree import TreeConfig, bulkload
from repro.workloads import get_preset, run_cluster_systems

CFG = TreeConfig(n_ms=2, nodes_per_ms=1024, fanout=8, n_locks_per_ms=512,
                 max_height=6, n_cs=4)
NET = NetConfig()
TINY = dict(load_records=2_000, ops=256, batch=128)


# --------------------------------------------------------------------------
# functional plane: the fleet is oracle-correct
# --------------------------------------------------------------------------

def _seed_writes(cl, keys, cs=0, chunk=64):
    """Feed ``keys`` through one CS in bounded waves (a scheduler round is
    a bounded batch; a fresh tree can't absorb hundreds of inserts in one
    wave's phase budget)."""
    for i in range(0, len(keys), chunk):
        kb = [None] * cl.n_cs
        kb[cs] = np.asarray(keys[i:i + chunk], np.int32)
        cl.write_wave(kb, [kb[c] for c in range(cl.n_cs)])


def test_cluster_waves_match_oracle():
    """Interleaved per-CS write/read waves stay oracle-correct: within a
    round, CS order is arrival order (§8's lane rule lifted to CSs)."""
    rng = np.random.default_rng(0)
    base = np.arange(0, 2_000, 4)
    cl = build_cluster(SHERMAN, CFG, n_clients=8, records=0)
    # records=0 => empty pool; seed it through the cluster itself
    oracle = OracleIndex()
    for c in range(cl.n_cs):
        _seed_writes(cl, base[c::cl.n_cs], cs=c)
        oracle.insert_batch(base[c::cl.n_cs], base[c::cl.n_cs])
    for rnd in range(4):
        keys = [rng.choice(base, size=16).astype(np.int32)
                for _ in range(cl.n_cs)]
        vals = [rng.integers(0, 1 << 20, size=16).astype(np.int32)
                for _ in range(cl.n_cs)]
        cl.write_wave(keys, vals)
        for k, v in zip(keys, vals):       # oracle applies in CS order
            oracle.insert_batch(k, v)
        probe = [rng.choice(base, size=24).astype(np.int32)
                 for _ in range(cl.n_cs)]
        got = cl.lookup_wave(probe)
        for p, (g, f) in zip(probe, got):
            for k, gi, fi in zip(p, g, f):
                want = oracle.lookup(int(k))
                assert fi and gi == want, (k, gi, want)
        cl.end_round()
    assert cl.conservation_ok()


def test_remote_splits_discovered_lazily():
    """The coherence tentpole: CS B is *not* fed CS A's split outputs —
    it discovers them on its own reads (stale path) or sweeps, and stays
    correct throughout."""
    cl = build_cluster(SHERMAN, CFG, n_clients=2, records=0,
                       sync_rounds=0)          # no periodic sweeps
    a, b = cl.nodes
    seed_k = np.arange(0, 2_000, 7, dtype=np.int32)
    _seed_writes(cl, seed_k)
    # warm B's private image, then split leaves via A only
    cl.lookup_wave([None, seed_k[:32]])
    assert b.counters["cache_hits"] > 0
    dense = np.arange(0, 600, 2, dtype=np.int32)   # dense => leaf splits
    _seed_writes(cl, dense)
    assert a.counters["leaf_splits"] > 0
    # A's own-cache hook fired; B's cache never heard of the splits
    assert a.cache.counters.invalidations + a.cache.counters.fills > 1
    b_inv_before = b.cache.counters.invalidations
    probe = dense[:64]
    got = cl.lookup_wave([None, probe])
    vals, found = got[1]
    assert found.all() and (vals == probe).all()
    # ... and only *now*, through its own stale reads, does B learn
    assert b.counters["cache_stale"] > 0
    assert b.cache.counters.invalidations > b_inv_before


def test_round_sweep_is_the_other_discovery_path():
    """With sync_rounds set, a CS that never reads still invalidates its
    stale entries through its periodic version sweep."""
    cl = build_cluster(SHERMAN, CFG, n_clients=2, records=0, sync_rounds=1)
    a, b = cl.nodes
    seed_k = np.arange(0, 2_000, 7, dtype=np.int32)
    _seed_writes(cl, seed_k)
    cl.lookup_wave([None, seed_k[:32]])            # warm B's image
    sweeps0 = b.cache.counters.sync_sweeps
    dense = np.arange(0, 600, 2, dtype=np.int32)
    _seed_writes(cl, dense)
    assert a.counters["leaf_splits"] > 0
    cl.end_round()                                 # B sweeps, no reads
    assert b.cache.counters.sync_sweeps > sweeps0
    assert b.cache.counters.invalidations > 0
    # swept-clean image: B's next lookups miss/refresh instead of chasing
    got = cl.lookup_wave([None, dense[:64]])
    vals, found = got[1]
    assert found.all() and (vals == dense[:64]).all()


# --------------------------------------------------------------------------
# performance plane: merged-trace conservation + GLT serialization
# --------------------------------------------------------------------------

def _cs_phase_sd(st, keys, cs_id, n_cs=4):
    n = keys.shape[0]
    k = jnp.asarray(keys, jnp.int32)
    _, _, stats, _ = write.write_phase(
        CFG, st, k, jnp.ones_like(k), jnp.zeros((n,), bool),
        jnp.ones((n,), bool), jnp.full((n,), cs_id, jnp.int32))
    return write_stats_dict(stats, np.ones(n, bool), np.zeros(n, bool),
                            int(st.height))


def _merge_case(feat, seed=3, n_cs=3, n=24):
    """Per-CS write-phase traces over one shared state (hot + fresh keys
    => cross-CS conflicts and splits)."""
    rng = np.random.default_rng(seed)
    base = rng.choice(20_000, size=600, replace=False)
    st = bulkload(CFG, base, base)
    traces = []
    for cs in range(n_cs):
        hot = rng.integers(0, 40, size=n // 2)
        new = rng.choice(np.setdiff1d(np.arange(20_000), base),
                         size=n // 2, replace=False)
        sd = _cs_phase_sd(st, np.concatenate([hot, new]), cs)
        traces.append(netsim.transformed_write_trace(sd, feat, NET, CFG))
    return traces


@pytest.mark.parametrize("feat", [SHERMAN, FG_PLUS], ids=["sherman", "fg+"])
def test_merge_conserves_per_cs_functional_counters(feat):
    """Merged per-CS traces conserve verb/byte/doorbell/CAS counts vs the
    sum of the per-CS functional counters, and the shared timeline can
    only be slower than any single CS alone."""
    traces = _merge_case(feat)
    sim, merged = netsim.price_merged_phase(traces, feat, NET, CFG)
    assert sim["verbs"] == sum(t.n_verbs for t in traces)
    assert sim["doorbells"] == sum(t.n_doorbells for t in traces)
    assert sim["cas_msgs"] == sum(t.n_cas for t in traces)
    assert sim["bytes"] == pytest.approx(
        sum(t.total_bytes for t in traces))
    assert merged.n_lanes == sum(t.n_lanes for t in traces)
    assert np.isfinite(sim["latency_s"]).all()
    solo = [netsim.simulate(t, NET, CFG.n_ms, feat.onchip)["makespan_s"]
            for t in traces]
    assert sim["makespan_s"] >= max(solo) * (1 - 1e-9)


def test_glt_chain_serializes_cross_cs_lock_conflicts():
    """Two CSs writing the same leaf: with the GLT chain, the second CS's
    entry LOCK gates on the first CS's release — the merged makespan
    grows by a full lock hold; without it the CSs falsely overlap."""
    def one_cs_trace():
        sd = dict(active=np.ones(1, bool), leaf=np.array([7]),
                  local_rank=np.zeros(1), node_rank=np.zeros(1, np.int64),
                  node_size=np.ones(1), cycle_head=np.ones(1, bool),
                  chain_end=np.ones(1, bool), split_lane=np.zeros(1, bool),
                  split_same_ms=np.zeros(1, bool),
                  split_new_row=np.zeros(1, np.int64),
                  cache_hit=np.ones(1, bool), height=2,
                  hocl_remote_cas=1, flat_remote_cas=1)
        return netsim.transformed_write_trace(sd, SHERMAN, NET, CFG)

    traces = [one_cs_trace(), one_cs_trace()]
    chained = V.merge_traces(traces, glt_chain=True)
    overlap = V.merge_traces(traces, glt_chain=False)
    # the second trace's entry LOCK picked up a cross-trace gate
    locks = np.nonzero(chained.role == V.LOCK)[0]
    assert (chained.dep2[locks] >= 0).sum() == 1
    assert (overlap.dep2[np.nonzero(overlap.role == V.LOCK)[0]] < 0).all()
    t_chain = netsim.simulate(chained, NET, CFG.n_ms, True)["makespan_s"]
    t_over = netsim.simulate(overlap, NET, CFG.n_ms, True)["makespan_s"]
    assert t_chain > t_over + NET.rtt_s          # >= one extra hold chain
    # conservation is untouched by the chaining rewrite
    assert chained.n_verbs == overlap.n_verbs == sum(
        t.n_verbs for t in traces)


def test_property_merge_conservation():
    """Hypothesis property: for arbitrary per-CS fleets (sizes, key
    skew), merged traces conserve verb/byte/doorbell counts vs the sum
    of per-CS functional counters — for both SHERMAN and FG+."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=12, deadline=None)
    @given(st.integers(min_value=1, max_value=4),       # fleet size
           st.integers(min_value=2, max_value=24),      # lanes per CS
           st.integers(min_value=0, max_value=2 ** 31 - 1))
    def inner(n_cs, n, seed):
        rng = np.random.default_rng(seed)
        base = rng.choice(20_000, size=400, replace=False)
        state = bulkload(CFG, base, base)
        for feat in (SHERMAN, FG_PLUS):
            traces = []
            for cs in range(n_cs):
                keys = rng.choice(base, size=n)     # live keys, shared
                sd = _cs_phase_sd(state, keys, cs)
                traces.append(netsim.transformed_write_trace(
                    sd, feat, NET, CFG))
            sim, merged = netsim.price_merged_phase(traces, feat, NET,
                                                    CFG)
            assert sim["verbs"] == sum(t.n_verbs for t in traces)
            assert sim["doorbells"] == sum(t.n_doorbells for t in traces)
            assert sim["cas_msgs"] == sum(t.n_cas for t in traces)
            assert sim["bytes"] == pytest.approx(
                sum(t.total_bytes for t in traces))
            assert np.isfinite(sim["makespan_s"])

    inner()


# --------------------------------------------------------------------------
# streams: shared hot set vs DEX-style partitioning
# --------------------------------------------------------------------------

def test_partitioned_streams_stay_in_shard():
    spec = get_preset("ycsb-a", **TINY)
    from repro.workloads.keygen import scramble
    strm = ClusterStreams(spec, 4, keyspace=1 << 20, partitioned=True,
                          seed=2)
    per = spec.load_records // 4
    for cs in range(4):
        keys = strm.draw(cs, 256)
        shard = set(scramble(
            np.arange(cs * per, (cs + 1) * per, dtype=np.int64),
            1 << 20).tolist())
        assert set(int(k) for k in keys) <= shard
    # strided insert cursors never collide across CSs
    ins = [strm.draw_insert(cs, 50) for cs in range(4)]
    allk = np.concatenate(ins)
    assert np.unique(allk).size == allk.size


def test_partitioning_removes_cross_cs_conflicts():
    """DEX's argument, observable in the merged plane: static partitions
    give each CS a private hot set, so cross-CS node conflicts (and the
    contention the merge chains) collapse vs the shared hot set."""
    spec = get_preset("write-only", theta=0.99, load_records=2_000,
                      ops=128, batch=64)
    res = {}
    for part in (False, True):
        cl = build_cluster(SHERMAN, CFG, n_clients=16,
                           records=spec.load_records)
        run_cluster(cl, spec, partitioned=part, seed=3)
        res[part] = cl.counters["cross_cs_conflicts"]
    assert res[False] > 0
    assert res[True] < res[False]


# --------------------------------------------------------------------------
# engine wiring + the scaling acceptance, miniature
# --------------------------------------------------------------------------

def test_cluster_run_result_breakdown_and_schema():
    spec = get_preset("ycsb-a", **TINY)
    (r,) = run_cluster_systems(spec, ("sherman",), CFG, n_clients=8,
                               seed=1)
    assert r.n_clients == 8 and r.rounds > 0
    assert len(r.per_cs) == CFG.n_cs
    assert sum(p["ops"] for p in r.per_cs) >= r.n_ops
    assert r.conservation_ok
    assert r.verbs == sum(p["verbs"] for p in r.per_cs)
    assert r.doorbells == sum(p["doorbells"] for p in r.per_cs)
    assert r.mops > 0 and np.isfinite(r.p99_us)
    d = json.loads(json.dumps(r.to_dict()))     # json-safe, round-trips
    assert d["per_cs"][0]["cs"] == 0


def test_scaling_advantage_grows_with_clients():
    """The acceptance curve in miniature: SHERMAN >= FG+ on write-heavy
    skew at the larger fleet, and the advantage grows with client
    count."""
    spec = get_preset("write-intensive", theta=0.99, load_records=2_000,
                      ops=192, batch=96)
    ratio = {}
    for nc in (4, 16):
        rs = {r.system: r
              for r in run_cluster_systems(spec, ("sherman", "fg+"), CFG,
                                           n_clients=nc, seed=1)}
        for r in rs.values():
            assert r.conservation_ok, (r.system, nc)
        ratio[nc] = rs["sherman"].mops / rs["fg+"].mops
    assert ratio[16] >= 1.0
    assert ratio[16] > ratio[4]


# --------------------------------------------------------------------------
# satellites: empty-run guards, spec mix rotation
# --------------------------------------------------------------------------

def test_empty_run_reports_zero_not_inf():
    """Satellite fixes: a zero-op run must neither crash the doorbell
    percentiles nor leak Infinity into the json export."""
    import math
    from repro.core import ShermanIndex
    from repro.workloads import run_workload
    idx = ShermanIndex.empty(CFG)
    assert idx.throughput_mops() == 0.0
    spec = get_preset("ycsb-a", load_records=0, ops=0, batch=128)
    r = run_workload(idx, spec, system="sherman")
    for v in (r.mops, r.doorbells_p50, r.doorbells_p99, r.p50_us,
              r.p99_us, r.write_bytes_median):
        assert math.isfinite(v), r
    assert r.mops == 0.0 and r.doorbells_p99 == 0.0
    json.dumps(r.to_dict())


def test_batch_counts_salt_realizes_weighted_mix_over_rounds():
    """One-lane per-CS batches still realize the *weighted* op mix
    across rounds (the fraction-proportional remainder draw the cluster
    scheduler relies on): a 95/5 mix stays ~95/5, never ~50/50."""
    spec = get_preset("ycsb-a")                  # 50/50 read/update
    kinds = {k for salt in range(4)
             for k, v in spec.batch_counts(1, salt=salt).items() if v}
    assert kinds == {"read", "update"}
    skewed = get_preset("ycsb-d")                # 95% read / 5% insert
    tally = {"read": 0, "insert": 0}
    for salt in range(200):
        for k, v in skewed.batch_counts(1, salt=salt).items():
            if v:
                tally[k] += v
    assert sum(tally.values()) == 200
    assert 180 <= tally["read"] <= 198, tally    # ~95%, not ~50%
    assert tally["insert"] >= 2, tally
    # full batches are exact: floors dominate, remainder < #kinds
    c = skewed.batch_counts(100)
    assert c["read"] == 95 and c["insert"] == 5


def test_merge_lane_cs_survives_empty_traces():
    """Per-CS lane attribution keeps the caller's positions even when a
    CS sat the wave out with an empty trace."""
    from repro.core.verbs import _empty_trace
    tr = _merge_case(SHERMAN, n_cs=2)
    merged = V.merge_traces([tr[0], _empty_trace(), tr[1]])
    lane_cs = merged.meta["lane_cs"]
    assert set(lane_cs.tolist()) == {0, 2}
    assert (lane_cs == 0).sum() == tr[0].n_lanes
    assert (lane_cs == 2).sum() == tr[1].n_lanes
