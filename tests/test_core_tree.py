"""Sherman core: bulkload, traversal, lookup, range, version protocol."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ShermanIndex, TreeConfig, OracleIndex
from repro.core import ops as O
from repro.core.tree import EMPTY_KEY, bulkload

CFG = TreeConfig(n_ms=2, nodes_per_ms=512, fanout=8, n_locks_per_ms=1024,
                 max_height=6, n_cs=2)


def make_index(n=300, seed=0):
    rng = np.random.default_rng(seed)
    keys = rng.choice(100_000, size=n, replace=False)
    vals = rng.integers(0, 1 << 20, size=n)
    idx = ShermanIndex.build(CFG, keys, vals)
    oracle = OracleIndex()
    oracle.insert_batch(keys, vals)
    return idx, oracle


def test_bulkload_structure():
    idx, _ = make_index()
    st = idx.state
    assert int(st.height) >= 2
    root_level = int(st.level[st.root])
    assert root_level == int(st.height) - 1
    # leaves chain left-to-right with increasing fences
    leaves = np.nonzero(np.asarray(st.level) == 0)[0]
    assert len(leaves) > 1


def test_lookup_hits_and_misses():
    idx, oracle = make_index()
    present = np.asarray([k for k, _ in oracle.items()[:64]])
    absent = np.asarray([100_001, 200_000, 300_000])
    v, f = idx.lookup(np.concatenate([present, absent]))
    assert f[:64].all() and not f[64:].any()
    for k, vv in zip(present, v[:64]):
        assert oracle.lookup(int(k)) == vv


def test_range_matches_oracle():
    idx, oracle = make_index()
    lo = np.asarray([0, 1_000, 50_000, 99_999])
    rk, rv, rn = idx.range(lo, count=10, max_leaves=40)
    for i, l in enumerate(lo):
        want = oracle.range(int(l), 10)
        got = [(int(a), int(b)) for a, b in zip(rk[i][:rn[i]],
                                                rv[i][:rn[i]])]
        assert got == want


def test_torn_read_detected_by_node_version():
    """Fig. 9: mismatched FNV/RNV must force a retry."""
    idx, oracle = make_index()
    k = oracle.items()[0][0]
    tr = O.traverse(CFG, idx.state, jnp.asarray([k], jnp.int32))
    leaf = int(tr.leaf[0])
    st = idx.state._replace(fnv=idx.state.fnv.at[leaf].add(1))  # torn image
    res = O.leaf_lookup(st, jnp.asarray([leaf]), jnp.asarray([k]))
    assert not bool(res.consistent[0])
    assert not bool(res.found[0])


def test_torn_entry_detected_by_entry_version():
    """Entry-level FEV/REV mismatch invalidates only that entry."""
    idx, oracle = make_index()
    k = oracle.items()[0][0]
    tr = O.traverse(CFG, idx.state, jnp.asarray([k], jnp.int32))
    leaf = int(tr.leaf[0])
    slot = int(np.nonzero(np.asarray(idx.state.keys[leaf]) == k)[0][0])
    st = idx.state._replace(fev=idx.state.fev.at[leaf, slot].add(1))
    res = O.leaf_lookup(st, jnp.asarray([leaf]), jnp.asarray([k]))
    assert not bool(res.consistent[0])
    # a different key in the same leaf is still readable
    others = [kk for kk in np.asarray(idx.state.keys[leaf])
              if kk != EMPTY_KEY and kk != k]
    if others:
        res2 = O.leaf_lookup(st, jnp.asarray([leaf]),
                             jnp.asarray([others[0]], jnp.int32))
        assert bool(res2.consistent[0])


def test_free_bit_invalidates_node():
    idx, oracle = make_index()
    k = oracle.items()[0][0]
    tr = O.traverse(CFG, idx.state, jnp.asarray([k], jnp.int32))
    leaf = int(tr.leaf[0])
    st = idx.state._replace(
        free_bit=idx.state.free_bit.at[leaf].set(True))
    res = O.leaf_lookup(st, jnp.asarray([leaf]), jnp.asarray([k]))
    assert not bool(res.consistent[0])


def test_bulkload_rejects_duplicates():
    with pytest.raises(ValueError):
        bulkload(CFG, np.asarray([1, 1, 2]), np.asarray([1, 2, 3]))
