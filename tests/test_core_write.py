"""Sherman write path: inserts, updates, deletes, splits, repairs."""
import numpy as np
import pytest

from repro.core import ShermanIndex, TreeConfig, OracleIndex

CFG = TreeConfig(n_ms=2, nodes_per_ms=512, fanout=8, n_locks_per_ms=1024,
                 max_height=6, n_cs=2)


def fresh(n=200, seed=1):
    rng = np.random.default_rng(seed)
    keys = rng.choice(50_000, size=n, replace=False)
    vals = rng.integers(0, 1 << 20, size=n)
    idx = ShermanIndex.build(CFG, keys, vals)
    oracle = OracleIndex()
    oracle.insert_batch(keys, vals)
    return idx, oracle, rng


def check_all(idx, oracle):
    items = oracle.items()
    if not items:
        return
    keys = np.asarray([k for k, _ in items])
    want = np.asarray([v for _, v in items])
    got, found = idx.lookup(keys)
    assert found.all(), f"missing {keys[~found][:10]}"
    assert (got == want).all()


def test_update_existing_keys():
    idx, oracle, rng = fresh()
    keys = np.asarray([k for k, _ in oracle.items()[:32]])
    vals = rng.integers(0, 100, size=32)
    idx.insert(keys, vals)
    oracle.insert_batch(keys, vals)
    check_all(idx, oracle)


def test_inserts_cause_splits_and_stay_consistent():
    idx, oracle, rng = fresh()
    for _ in range(8):
        ks = rng.integers(0, 50_000, size=96)
        vs = rng.integers(0, 1 << 20, size=96)
        idx.insert(ks, vs)
        oracle.insert_batch(ks, vs)
    assert idx.counters["leaf_splits"] > 0
    check_all(idx, oracle)


def test_root_split_grows_height():
    cfg = TreeConfig(n_ms=2, nodes_per_ms=512, fanout=4,
                     n_locks_per_ms=512, max_height=8, n_cs=2)
    idx = ShermanIndex.build(cfg, np.asarray([10]), np.asarray([1]))
    oracle = OracleIndex()
    oracle.insert(10, 1)
    h0 = int(idx.state.height)
    rng = np.random.default_rng(3)
    for _ in range(12):
        ks = rng.choice(10_000, size=32, replace=False)
        vs = ks * 2
        idx.insert(ks, vs)
        oracle.insert_batch(ks, vs)
    assert int(idx.state.height) > h0
    assert idx.counters["root_splits"] >= 1
    check_all(idx, oracle)


def test_delete_then_reinsert():
    idx, oracle, rng = fresh()
    keys = np.asarray([k for k, _ in oracle.items()[:24]])
    idx.delete(keys)
    oracle.delete_batch(keys)
    _, found = idx.lookup(keys)
    assert not found.any()
    idx.insert(keys, keys * 3)
    oracle.insert_batch(keys, keys * 3)
    check_all(idx, oracle)


def test_intra_batch_last_op_wins():
    idx, oracle, _ = fresh()
    k = oracle.items()[0][0]
    # same key three times in one batch: last lane's value sticks
    idx.insert(np.asarray([k, k, k]), np.asarray([1, 2, 3]))
    v, f = idx.lookup(np.asarray([k]))
    assert f[0] and v[0] == 3


def test_duplicate_new_key_insert_once():
    idx, oracle, _ = fresh()
    idx.insert(np.asarray([77_777] * 5), np.arange(5))
    v, f = idx.lookup(np.asarray([77_777]))
    assert f[0] and v[0] == 4
    # no duplicate entries: delete once removes it completely
    idx.delete(np.asarray([77_777]))
    _, f = idx.lookup(np.asarray([77_777]))
    assert not f[0]


def test_handover_counted_under_contention():
    idx, _, rng = fresh()
    hot = np.full(64, 4_242)
    idx.insert(hot, np.arange(64))
    assert idx.counters["handovers"] > 0
