"""Multi-device tests in a subprocess (XLA_FLAGS must precede jax import).

Covers: routed shard_map lookup, pjit write phase on the sharded pool,
sharded train step, and elastic re-meshing after a simulated device loss.
"""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.core import ShermanIndex, TreeConfig
from repro.core import sharded as S
from repro.core.write import RepairQueue

cfg = TreeConfig(n_ms=4, nodes_per_ms=256, fanout=8, n_locks_per_ms=512,
                 max_height=6, n_cs=2)
rng = np.random.default_rng(1)
keys = rng.choice(50_000, size=400, replace=False)
vals = rng.integers(0, 1 << 20, size=400)
idx = ShermanIndex.build(cfg, keys, vals)

from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(2, 4)
st = S.shard_tree(idx.state, mesh, cfg)
cache = S.build_cache(cfg, idx.state, depth=3)
fn = S.routed_lookup_fn(cfg, mesh, depth=3)
q = jnp.asarray(keys[:64], jnp.int32)
with mesh:
    r = fn(st, cache, q)
assert np.asarray(r.found).all()
assert (np.asarray(r.value) == vals[:64]).all()
print("routed-lookup-ok")

wp = S.pjit_phase_fns(cfg, mesh)
b = 64
wk = jnp.asarray(rng.integers(0, 50_000, size=b), jnp.int32)
wv = jnp.asarray(rng.integers(0, 100, size=b), jnp.int32)
with mesh:
    st2, done, stats, rq = wp(st, wk, wv, jnp.zeros(b, bool),
                              jnp.ones(b, bool), jnp.zeros(b, jnp.int32),
                              RepairQueue.empty(b))
assert bool(done.all())
print("pjit-write-ok")

# sharded train step + elastic reshard
from repro.configs import get_reduced
from repro.models.registry import build, make_batch
from repro.launch.train import shard_train_fns
from repro.launch import elastic
from repro.optim import adamw

api = build(get_reduced("smollm-135m"))
params = api.init(jax.random.PRNGKey(0))
opt = adamw.init(params)
batch = make_batch(api.cfg, batch=4, seq=16)
step, _ = shard_train_fns(api, mesh, params, opt, batch,
                          adamw.AdamWConfig(warmup_steps=1, total_steps=5))
p = jax.device_put(params)
params2, opt2, m = step(params, opt, batch)
assert np.isfinite(float(m["loss"]))
print("sharded-train-ok")

mesh2 = elastic.drop_devices(mesh, 4)          # lose half the fleet
assert int(np.prod(list(mesh2.shape.values()))) == 4
params3 = elastic.reshard_params(params2, mesh2)
step2, _ = shard_train_fns(api, mesh2, params3,
                           jax.device_get(opt2), batch,
                           adamw.AdamWConfig(warmup_steps=1, total_steps=5))
opt3 = jax.device_get(opt2)
params4, opt4, m2 = step2(params3, opt3, batch)
assert np.isfinite(float(m2["loss"]))
print("elastic-ok")
"""


@pytest.mark.slow
def test_distributed_all():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    for marker in ("routed-lookup-ok", "pjit-write-ok",
                   "sharded-train-ok", "elastic-ok"):
        assert marker in out.stdout, (marker, out.stdout, out.stderr[-1500:])
