"""HOCL conflict-group decomposition invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # optional dep: property test skipped below
    st = None

from repro.core import hocl
from repro.core.tree import TreeConfig

CFG = TreeConfig(n_ms=2, nodes_per_ms=64, fanout=4, n_locks_per_ms=64,
                 max_height=4, n_cs=4, handover_max=4)


def groups_of(nodes, cs, active=None):
    nodes = jnp.asarray(nodes, jnp.int32)
    cs = jnp.asarray(cs, jnp.int32)
    act = jnp.ones(nodes.shape, bool) if active is None else \
        jnp.asarray(active)
    return hocl.group_by_node(CFG, nodes, cs, act)


def test_single_group_ranks():
    g = groups_of([5, 5, 5, 5], [0, 0, 0, 0])
    assert list(np.asarray(g.local_rank)) == [0, 1, 2, 3]
    assert list(np.asarray(g.local_size)) == [4, 4, 4, 4]
    assert int(g.n_node_groups) == 1 and int(g.n_local_groups) == 1
    # 4 ops, MAX_DEPTH=4 handovers per cycle => 1 remote lock cycle
    assert list(np.asarray(g.lock_cycles)) == [1, 1, 1, 1]


def test_handover_depth_cap():
    g = groups_of([7] * 11, [0] * 11)
    # 11 ops = ceil(11/5) = 3 lock cycles (paper MAX_DEPTH=4)
    assert int(g.lock_cycles[0]) == 3


def test_cross_cs_serialization_rank():
    g = groups_of([9, 9, 9, 9], [0, 0, 1, 1])
    cs_rank = np.asarray(g.cs_rank)
    assert cs_rank[0] == 0 and cs_rank[1] == 0
    assert cs_rank[2] == 1 and cs_rank[3] == 1
    assert list(np.asarray(g.n_cs_on_node)) == [2, 2, 2, 2]


def test_inactive_lanes_excluded():
    g = groups_of([3, 3, 3], [0, 0, 0], active=[True, False, True])
    assert int(g.n_node_groups) == 1
    sizes = np.asarray(g.local_size)
    assert sizes[0] == 2 and sizes[2] == 2


def _check_group_invariants(ops):
    nodes = [n for n, _ in ops]
    cs = [c for _, c in ops]
    g = groups_of(nodes, cs)
    node_rank = np.asarray(g.node_rank)
    node_size = np.asarray(g.node_size)
    local_rank = np.asarray(g.local_rank)
    local_size = np.asarray(g.local_size)
    arr = np.asarray(nodes)
    csarr = np.asarray(cs)
    for nid in set(nodes):
        lanes = np.nonzero(arr == nid)[0]
        # node group sizes consistent; ranks form a permutation
        assert (node_size[lanes] == len(lanes)).all()
        assert sorted(node_rank[lanes]) == list(range(len(lanes)))
        # FIFO within each (node, cs) local queue (node ordering is by CS)
        for c in set(csarr[lanes]):
            ll = lanes[csarr[lanes] == c]
            assert (np.diff(node_rank[ll]) > 0).all()
            assert (np.diff(local_rank[ll]) == 1).all()
    # local ranks below local sizes
    assert (local_rank < local_size).all()
    # handover accounting: cycles = ceil(k / (depth+1))
    k = local_size
    assert (np.asarray(g.lock_cycles) ==
            (k + CFG.handover_max) // (CFG.handover_max + 1)).all()


if st is not None:
    test_group_invariants = settings(max_examples=30, deadline=None)(
        given(st.lists(st.tuples(st.integers(0, 20), st.integers(0, 3)),
                       min_size=1, max_size=64))(_check_group_invariants))
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_group_invariants():
        _check_group_invariants([(0, 0)])
