"""Per-kernel shape/dtype sweeps: interpret-mode Pallas vs jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.leaf_search.kernel import leaf_search
from repro.kernels.leaf_search.ref import leaf_search_ref
from repro.kernels.rwkv_scan.kernel import wkv6
from repro.kernels.rwkv_scan.ref import wkv6_ref

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("b,h,kv,s,hd,causal,dtype", [
    (2, 4, 2, 256, 64, True, jnp.float32),
    (1, 8, 8, 128, 128, False, jnp.float32),
    (2, 2, 1, 512, 32, True, jnp.float32),
    (1, 4, 4, 256, 64, True, jnp.bfloat16),
    (3, 6, 2, 128, 64, False, jnp.float32),
])
def test_flash_attention(b, h, kv, s, hd, causal, dtype):
    q = jnp.asarray(RNG.standard_normal((b, h, s, hd)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, kv, s, hd)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, kv, s, hd)), dtype)
    out = flash_attention(q, k, v, causal=causal, bq=64, bk=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("b,f,bt", [(256, 8, 64), (512, 16, 128),
                                    (128, 32, 128), (256, 64, 256)])
def test_leaf_search(b, f, bt):
    keys = np.stack([RNG.choice(9_000, f, replace=False)
                     for _ in range(b)]).astype(np.int32)
    vals = RNG.integers(0, 1 << 20, (b, f)).astype(np.int32)
    q = np.where(RNG.random(b) < 0.5,
                 keys[np.arange(b), RNG.integers(0, f, b)],
                 20_000 + np.arange(b)).astype(np.int32)
    fev = RNG.integers(0, 4, (b, f)).astype(np.int32)
    rev = fev.copy()
    rev[: b // 8] += 1
    fnv = RNG.integers(0, 4, b).astype(np.int32)
    rnv = fnv.copy()
    rnv[b // 8: b // 4] += 1
    free = np.zeros(b, np.int32)
    free[b // 4: b // 4 + 4] = 1
    args = [jnp.asarray(a) for a in (q, keys, vals, fev, rev, fnv, rnv,
                                     free)]
    got = leaf_search(*args, bt=bt, interpret=True)
    want = leaf_search_ref(*args)
    for g, w in zip(got, want):
        assert (np.asarray(g) == np.asarray(w)).all()


@pytest.mark.parametrize("b,h,t,n,bt,dtype", [
    (2, 3, 256, 32, 64, jnp.float32),
    (1, 2, 128, 64, 128, jnp.float32),
    (2, 1, 512, 16, 64, jnp.float32),
    (1, 2, 128, 64, 32, jnp.bfloat16),
])
def test_wkv6(b, h, t, n, bt, dtype):
    r = jnp.asarray(RNG.standard_normal((b, h, t, n)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, h, t, n)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, h, t, n)), dtype)
    w = jnp.asarray(RNG.random((b, h, t, n)) * 0.5 + 0.45, dtype)
    u = jnp.asarray(RNG.standard_normal((h, n)), dtype)
    out = wkv6(r, k, v, w, u, bt=bt, interpret=True)
    ref = wkv6_ref(r, k, v, w, u)
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=tol, rtol=tol)


def test_chunked_sdpa_matches_naive():
    """The jnp flash twin used by the perf configs must equal naive SDPA."""
    from repro.models.attention import _sdpa_chunked, _sdpa_naive
    q = jnp.asarray(RNG.standard_normal((2, 256, 4, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 256, 2, 32)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 256, 2, 32)), jnp.float32)
    for causal in (True, False):
        a = _sdpa_chunked(q, k, v, causal=causal, chunk=64)
        b = _sdpa_naive(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)
