"""Per-architecture smoke tests (reduced configs) + decode/train parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get, get_reduced
from repro.models.registry import build, make_batch


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_exact_config_values(name):
    cfg = get(name)
    # spot-check assigned numbers survive in the exact configs
    table = {
        "llama4_scout_17b_a16e": (48, 5120, 40, 8, 8192, 202048),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, 1408, 151936),
        "command_r_35b": (40, 8192, 64, 8, 22528, 256000),
        "deepseek_67b": (95, 8192, 64, 8, 22016, 102400),
        "smollm_135m": (30, 576, 9, 3, 1536, 49152),
        "granite_3_8b": (40, 4096, 32, 8, 12800, 49155),
        "rwkv6_1_6b": (24, 2048, 0, 0, 7168, 65536),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "whisper_medium": (24, 1024, 16, 16, 4096, 51865),
        "internvl2_1b": (24, 896, 14, 2, 4864, 151655),
    }[name]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == table


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_reduced_smoke_forward_and_decode(name):
    cfg = get_reduced(name)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, batch=2, seq=16)
    loss = jax.jit(api.loss)(params, batch)
    assert np.isfinite(float(loss))
    st = api.decode_init(params, batch, 32)
    logits, st2 = jax.jit(api.decode_step)(params, st,
                                           batch["tokens"][:, 0])
    assert logits.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_one_train_step_reduces_loss_direction(name):
    """One SGD-ish step on a fixed batch should not blow up the loss."""
    from repro.optim import adamw
    cfg = get_reduced(name)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(1))
    batch = make_batch(cfg, batch=2, seq=8)
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    state = adamw.init(params)
    loss0, grads = jax.value_and_grad(api.loss)(params, batch)
    params2, state, _ = adamw.update(opt, grads, state, params)
    loss1 = api.loss(params2, batch)
    assert np.isfinite(float(loss1))
    assert float(loss1) < float(loss0) + 1.0


def test_prefill_decode_parity_transformer():
    """prefill(tokens) then decode_step must agree with full forward."""
    from repro.models import transformer as T
    cfg = get_reduced("smollm_135m")
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(2))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0,
                              cfg.vocab, jnp.int32)
    full = T.forward(params, toks, cfg, remat=False)
    logits_p, st = api.prefill(params, {"tokens": toks[:, :11]}, 16)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full[:, 10]), atol=2e-3,
                               rtol=2e-3)
    logits_d, st = api.decode_step(params, st._replace(pos=jnp.int32(11)),
                                   toks[:, 11])
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(full[:, 11]), atol=2e-3,
                               rtol=2e-3)


def test_decode_matches_forward_rwkv():
    """Step-by-step decode must reproduce the training forward's logits."""
    from repro.models import rwkv6 as R
    cfg = dataclasses.replace(get_reduced("rwkv6_1_6b"),
                              dtype=jnp.float32)
    params = R.init_rwkv(jax.random.PRNGKey(4), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, 6), 0, cfg.vocab,
                              jnp.int32)
    full = R.forward(params, toks, cfg)
    st = R.init_state(cfg, 1)
    outs = []
    for t in range(6):
        lg, st = R.decode_step(params, st, toks[:, t], cfg)
        outs.append(lg)
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               atol=1e-3, rtol=1e-3)


def test_decode_matches_forward_griffin():
    from repro.models import rglru as G
    cfg = dataclasses.replace(get_reduced("recurrentgemma_2b"),
                              dtype=jnp.float32, n_layers=3)
    params = G.init_griffin(jax.random.PRNGKey(6), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, 6), 0, cfg.vocab,
                              jnp.int32)
    full = G.forward(params, toks, cfg)
    st = G.init_state(cfg, 1)
    outs = []
    for t in range(6):
        lg, st = G.decode_step(params, st, toks[:, t], cfg)
        outs.append(lg)
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               atol=2e-3, rtol=2e-3)


def test_unroll_matches_scan():
    cfg = dataclasses.replace(get_reduced("smollm_135m"),
                              dtype=jnp.float32)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(8))
    batch = make_batch(cfg, batch=2, seq=8)
    l1 = api.loss(params, batch)
    cfg2 = dataclasses.replace(cfg, unroll_layers=True)
    api2 = build(cfg2)
    l2 = api2.loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), atol=1e-4)


def test_long_500k_support_flags():
    from repro.launch.shapes import SHAPES, cell_supported
    sub = {n: get(n).subquadratic for n in ALL_ARCHS}
    assert sub["rwkv6_1_6b"] and sub["recurrentgemma_2b"]
    assert sum(sub.values()) == 2
    for n in ALL_ARCHS:
        ok, why = cell_supported(get(n), SHAPES["long_500k"])
        assert ok == sub[n]
        if not ok:
            assert "sub-quadratic" in why
