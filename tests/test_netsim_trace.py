"""The RDMA verb-trace plane: conservation between the functional plane's
structural counters and the event simulator, feature toggles as pure trace
transformations, event-loop semantics, and the ablation ladder."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import ShermanIndex, TreeConfig, netsim, verbs as V, write
from repro.core.api import write_stats_dict
from repro.core.netsim import (ABLATION_LADDER, FG_PLUS, SHERMAN, Features,
                               NetConfig)
from repro.core.tree import bulkload
from repro.workloads import SYSTEMS, build_index, get_preset, run_systems

CFG = TreeConfig(n_ms=2, nodes_per_ms=1024, fanout=8, n_locks_per_ms=512,
                 max_height=6, n_cs=4)
NET = NetConfig()
TINY = dict(load_records=2_000, ops=256, batch=128)


def _one_write_phase(n=96, seed=7):
    """Run one raw write phase (hot keys + fresh keys => contention and
    splits) and return its stats dict, the way api.py feeds netsim."""
    rng = np.random.default_rng(seed)
    base = rng.choice(20_000, size=600, replace=False)
    st = bulkload(CFG, base, base)
    hot = rng.integers(0, 40, size=n // 2)
    new = rng.choice(np.setdiff1d(np.arange(20_000), base), size=n // 2,
                     replace=False)
    keys = jnp.asarray(np.concatenate([hot, new]), jnp.int32)
    vals = jnp.ones_like(keys)
    active = jnp.ones((n,), bool)
    cs = jnp.asarray(np.arange(n) % CFG.n_cs, jnp.int32)
    _, _, stats, _ = write.write_phase(CFG, st, keys, vals,
                                       jnp.zeros((n,), bool), active, cs)
    return write_stats_dict(stats, np.ones(n, bool), np.zeros(n, bool),
                            int(st.height))


def _expected_write_totals(sd, feat):
    """Independent closed-form reconstruction of the verb stream from the
    functional plane's structural counters (the conservation oracle)."""
    act = np.asarray(sd["active"], bool)
    n = int(act.sum())
    height = max(int(sd["height"]), 1)
    reads = int(np.where(np.asarray(sd["cache_hit"])[act], 1, height).sum())
    splits = int(np.asarray(sd["split_lane"])[act].sum())
    if feat.hierarchical:
        cas = int(sd["hocl_remote_cas"])
        unlocks = int(np.asarray(sd["chain_end"])[act].sum())
    else:
        cas = int(sd["flat_remote_cas"])       # lock CAS + spin retries
        unlocks = n
    msgs = reads + cas + n + 2 * splits + unlocks
    nb, eb = CFG.node_bytes, CFG.entry_bytes
    wb = splits * nb + (n - splits) * (eb if feat.twolevel else nb)
    bytes_ = (reads * nb + wb + splits * (nb + eb)
              + (cas + unlocks) * V.LOCK_BYTES)
    return msgs, cas, bytes_


@pytest.mark.parametrize("feat", [SHERMAN, FG_PLUS],
                         ids=["sherman", "fg+"])
def test_write_trace_conservation(feat):
    """The event simulator's totals equal the functional plane's
    structural counters — bytes and messages are conserved across the
    plane boundary for both full Sherman and the FG+ baseline."""
    sd = _one_write_phase()
    priced = netsim.price_write_phase(sd, feat, NET, CFG)
    msgs, cas, bytes_ = _expected_write_totals(sd, feat)
    assert priced["msgs"] == priced["verbs"] == msgs
    assert priced["cas_msgs"] == cas
    assert priced["bytes"] == pytest.approx(bytes_)
    assert priced["makespan_s"] > 0 and np.isfinite(priced["makespan_s"])
    assert priced["latency_s"].shape[0] == int(
        np.asarray(sd["active"]).sum())
    assert np.isfinite(priced["latency_s"]).all()


def test_hocl_cycle_masks_match_lock_counters():
    """The verb plane's per-lane cycle masks agree with hocl's scalar
    counters: #LOCK CAS == hocl_remote_cas, and every handover cycle has
    exactly one head and one end."""
    sd = _one_write_phase()
    act = np.asarray(sd["active"], bool)
    heads = int(np.asarray(sd["cycle_head"])[act].sum())
    ends = int(np.asarray(sd["chain_end"])[act].sum())
    assert heads == sd["hocl_remote_cas"]
    assert ends == heads


def test_combine_is_a_pure_doorbell_merge():
    """§4.5: combining changes *when* verbs post, not what is posted —
    verbs and bytes identical, doorbell rings strictly fewer."""
    sd = _one_write_phase()
    on = netsim.price_write_phase(sd, SHERMAN, NET, CFG)
    off = netsim.price_write_phase(
        sd, Features(combine=False, onchip=True, hierarchical=True,
                     twolevel=True), NET, CFG)
    assert on["verbs"] == off["verbs"]
    assert on["bytes"] == pytest.approx(off["bytes"])
    assert on["doorbells"] < off["doorbells"]
    assert off["doorbells"] == off["verbs"]     # no merging without combine
    assert on["makespan_s"] <= off["makespan_s"]


def test_event_loop_doorbell_semantics():
    """A dependent verb costs a full extra round trip; riding the same
    doorbell (in-order delivery) removes it — the §4.5 mechanism itself,
    checked at event-loop granularity."""
    def two_writes(share_doorbell):
        dep = np.array([-1, 0 if not share_doorbell else -1], np.int64)
        return V.VerbTrace(
            kind=np.full(2, V.WRITE, np.int8),
            role=np.array([V.WRITEBACK, V.UNLOCK], np.int8),
            ms=np.zeros(2, np.int32), nbytes=np.full(2, 16, np.int64),
            lane=np.zeros(2, np.int32),
            doorbell=np.array([0, 0 if share_doorbell else 1], np.int64),
            dep=dep, dep2=np.full(2, -1, np.int64), at=np.zeros(2),
            n_lanes=1)
    chained = netsim.simulate(two_writes(False), NET, 1, True)
    merged = netsim.simulate(two_writes(True), NET, 1, True)
    assert chained["makespan_s"] > 1.9 * NET.rtt_s   # two sequential RTTs
    assert merged["makespan_s"] < 1.5 * NET.rtt_s    # one ring, one RTT
    assert merged["doorbells"] == 1 and chained["doorbells"] == 2


def test_ablation_ladder_monotone_throughput():
    """Fig. 10/11: each technique is non-regressive on a write-heavy
    skewed YCSB-A batch (2% numerical slack)."""
    spec = get_preset("ycsb-a", **TINY)
    ladder = [nm.lower() for nm, _ in ABLATION_LADDER]
    mops = [r.mops for r in run_systems(spec, ladder, CFG)]
    assert all(np.isfinite(m) and m > 0 for m in mops)
    for a, b in zip(mops, mops[1:]):
        assert b >= 0.98 * a, (ladder, mops)


def test_sherman_doorbells_and_tail_acceptance():
    """The headline acceptance: Sherman posts strictly fewer doorbells
    than combine=False and its simulated p99 is finite and degrades when
    the lock hierarchy is disabled."""
    spec = get_preset("ycsb-a", **TINY)
    res = {r.system: r
           for r in run_systems(spec, ("sherman", "sherman-nocombine",
                                       "sherman-flat"), CFG)}
    sh = res["sherman"]
    assert sh.doorbells < res["sherman-nocombine"].doorbells
    assert sh.verbs == sh.doorbells + sh.doorbells_saved
    assert sh.doorbells_saved > 0
    assert 0 < sh.p99_us < np.inf
    assert res["sherman-flat"].p99_us > sh.p99_us


def test_read_trace_conservation_without_cache():
    """Cache off => every lookup replays exactly ``height`` TRAVERSE
    reads; simulator messages match the functional read counters."""
    rng = np.random.default_rng(3)
    base = rng.choice(50_000, size=2_000, replace=False)
    idx = ShermanIndex.build(CFG, base, base, cache_bytes=0)
    n, height = 256, int(idx.state.height)
    m0, r0 = idx.counters["msgs"], idx.counters["lookup_reads"]
    idx.lookup(base[:n].astype(np.int32))
    assert idx.counters["msgs"] - m0 == n * height
    assert idx.counters["lookup_reads"] - r0 == n * height
    assert idx.counters["doorbells"] == idx.counters["verbs"]  # reads never
    # combine: the next address depends on the previous read (§4.5)


def test_empty_scan_retries_clamped():
    """Satellite: an empty scan must not price negative retries."""
    idx = ShermanIndex.empty(CFG)
    k, v, n = idx.range(np.asarray([123], np.int32), count=4)
    assert int(n[0]) == 0
    assert idx.counters["sim_time_s"] > 0          # still paid the descent
    # direct: a negative retry count is clamped, not subtracted
    priced = netsim.price_read_phase(
        dict(active=np.ones(4, bool), cache_hit=np.zeros(4, bool),
             retries=np.full(4, -1), leaf=np.zeros(4, np.int64), scan=True,
             height=2),
        SHERMAN, NET, CFG)
    assert priced["msgs"] == 4 * 2
    assert (np.asarray(priced["lane_doorbells"]) >= 1).all()


def test_write_ops_counted_once_across_retry_phases():
    """Satellite: resubmitted lanes no longer inflate the throughput
    numerator — client ops count once, retries separately."""
    cfg = TreeConfig(n_ms=2, nodes_per_ms=512, fanout=4, n_locks_per_ms=256,
                     max_height=8, n_cs=2)
    idx = ShermanIndex.build(cfg, np.arange(0, 640, 10), np.arange(64))
    keys = np.arange(0, 256, 2).astype(np.int32)   # dense: forces splits
    idx.insert(keys, keys)
    assert idx.counters["write_ops"] == keys.size
    assert idx.counters["retried_ops"] > 0
    assert idx.counters["leaf_splits"] > 0
    got, found = idx.lookup(keys)
    assert found.all() and (got == keys).all()


def test_release_gates_conserve_totals():
    """Open-loop release gates (per-lane arrival floors) change *when*
    verbs post, never what is posted: structural totals are conserved,
    no verb starts before its op's arrival, and both replay engines stay
    pinned verb-for-verb on the gated trace (latency and per-lane
    queueing attribution alike)."""
    sd = _one_write_phase()
    tr = netsim.transformed_write_trace(sd, SHERMAN, NET, CFG)
    rng = np.random.default_rng(5)
    rel = np.sort(rng.uniform(0.0, 5e-5, tr.n_lanes))
    gated = V.shift_release(tr, rel)
    base = netsim.simulate(tr, NET, CFG.n_ms, True)
    sim = netsim.simulate(gated, NET, CFG.n_ms, True)
    ref = netsim.simulate_ref(gated, NET, CFG.n_ms, True)
    for k in ("msgs", "verbs", "doorbells", "bytes", "cas_msgs"):
        assert sim[k] == base[k], k
    lm = gated.lane >= 0
    assert (sim["verb_start_s"][lm] >= rel[gated.lane[lm]] - 1e-12).all()
    assert np.array_equal(sim["latency_s"], ref["latency_s"])
    assert np.array_equal(sim["lane_queue_s"], ref["lane_queue_s"])
    # an op's completion can never precede its own release
    assert (sim["latency_s"] >= rel - 1e-12).all()


def test_single_verb_latency_decomposition():
    """For a single-verb op the reported (absolute) completion decomposes
    exactly: arrival + queueing delay + service + RTT — the accounting
    identity the serving plane's queue/service split relies on."""
    from repro.serve import poisson_arrivals, station_trace
    arr = poisson_arrivals(4e5, 512, seed=2) / netsim.PS_PER_S
    tr = station_trace(arr, 12_500, n_ms=2)
    sim = netsim.simulate(tr, NET, 2, True)
    svc = np.rint(max(1.0 / NET.nic_iops_small, 12_500 / NET.nic_bw_Bps)
                  * netsim.PS_PER_S) / netsim.PS_PER_S
    rtt = round(NET.rtt_s * netsim.PS_PER_S) / netsim.PS_PER_S
    want = arr + sim["lane_queue_s"] + svc + rtt
    assert np.allclose(sim["latency_s"], want, rtol=0, atol=1e-12)
    assert (sim["verb_start_s"] >= arr - 1e-12).all()
    assert (sim["lane_queue_s"] >= 0).all()


def test_run_result_reports_verb_plane(tmp_path):
    """RunResult carries the verb/doorbell/combine-savings fields and they
    serialize."""
    import json
    spec = get_preset("ycsb-a", **TINY)
    idx = build_index(SYSTEMS["sherman"], CFG, records=spec.load_records)
    from repro.workloads import run_workload
    r = run_workload(idx, spec, system="sherman")
    assert r.verbs > 0 and r.doorbells > 0
    assert r.doorbells_saved == r.verbs - r.doorbells > 0
    json.dumps(r.to_dict())


def test_server_clock_reset_ms_clears_backlog():
    """Crash semantics of the carried clock: a restarted MS serves a
    fresh verb at the bare service+RTT floor from the restart tick,
    while a non-reset twin still queues it behind the pre-crash backlog.
    The on-NIC queue died with the server — the frontier must not
    carry it."""
    from repro.serve import station_trace

    # pile a deep backlog onto MS 0 (all ops arrive at t=0)
    backlog = station_trace(np.zeros(64), 4096, n_ms=1)
    clock = netsim.ServerClock.fresh(2)
    netsim.simulate(backlog, NET, 2, True, clock=clock)
    busy_s = clock.nic_free_ps[0] / netsim.PS_PER_S
    assert busy_s > 0

    stale = netsim.ServerClock(clock.nic_free_ps.copy(),
                               clock.atomic_free_ps.copy())
    restart_s = busy_s / 4                   # restart well before the
    clock.reset_ms(0, restart_s)             # phantom backlog would end
    assert clock.nic_free_ps[0] == clock.atomic_free_ps[0] \
        == np.int64(round(restart_s * netsim.PS_PER_S))
    assert clock.nic_free_ps[1] == stale.nic_free_ps[1]  # others untouched

    # single verb released at the restart: served immediately
    probe_at = np.array([restart_s])
    probe = station_trace(probe_at, 4096, n_ms=1)
    svc = max(1.0 / NET.nic_iops_small, 4096 / NET.nic_bw_Bps)
    floor = np.rint(svc * netsim.PS_PER_S) / netsim.PS_PER_S \
        + round(NET.rtt_s * netsim.PS_PER_S) / netsim.PS_PER_S
    done_fresh = netsim.simulate(probe, NET, 2, True,
                                 clock=clock)["latency_s"][0]
    done_stale = netsim.simulate(probe, NET, 2, True,
                                 clock=stale)["latency_s"][0]
    assert done_fresh == pytest.approx(restart_s + floor, abs=1e-12)
    assert done_stale > done_fresh           # phantom queueing without reset
