"""Observability plane (DESIGN.md §14): recorder neutrality, span
conservation, and tail-forensics exactness.

Three contracts, asserted across the ablation ladder and all three
execution planes (single-frontend closed loop, merged cluster waves,
open-loop serving on a carried clock):

1. **Neutrality** — attaching a :class:`repro.obs.Recorder` is a pure
   observation: every reported number (per-lane latencies, queueing,
   counters, percentiles) is bit-identical to the unrecorded run.
2. **Span conservation** — recorded per-MS NIC / atomic-unit busy spans
   are non-overlapping per FIFO, reconcile with each verb's completion
   tick with integer equality, and sum to the simulator's busy time;
   closed-loop segments tile the engine's accumulated ``sim_time_s``.
3. **Attribution exactness** — the tail-forensics critical-path walk
   decomposes every op's latency into nic_queue + atomic_ser +
   lock_wait + service with zero integer residual, and the HOCL ladder
   rung shifts tail attribution from lock-protocol wait to NIC/data
   time (the Fig. 10/11 story, now measurable per op).

Plus seeded + hypothesis properties over randomly release-gated traces.
"""
import json

import numpy as np
import pytest

from repro.core import TreeConfig, netsim, verbs as V
from repro.core.netsim import (ABLATION_LADDER, FG_PLUS, SHERMAN, NetConfig,
                               ServerClock)
from repro.obs import (Recorder, attribute_ops, span_accounting, summarize,
                       timeseries, to_chrome_trace, write_chrome_trace)
from repro.workloads import SYSTEMS, build_index, get_preset, run_systems
from repro.workloads.engine import (run_cluster_systems,
                                    run_open_loop_systems, run_workload)

from tests.test_netsim_trace import _one_write_phase

CFG = TreeConfig(n_ms=2, nodes_per_ms=1024, fanout=8, n_locks_per_ms=512,
                 max_height=6, n_cs=4)
NET = NetConfig()
TINY = dict(load_records=2_000, ops=256, batch=128)


def _sim_equal(a: dict, b: dict) -> None:
    assert a.keys() == b.keys()
    for k in a:
        x, y = a[k], b[k]
        if isinstance(x, np.ndarray):
            assert np.array_equal(x, y), k
        else:
            assert x == y, k


def _result_equal(a, b) -> None:
    """Two RunResults are identical apart from the obs payload."""
    da, db = a.to_dict(), b.to_dict()
    da.pop("obs"), db.pop("obs")
    assert da == db


# --------------------------------------------------------------------------
# 1. neutrality: recording is a pure observation
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine", [netsim.simulate, netsim.simulate_ref],
                         ids=["wavefront", "ref"])
def test_engine_neutrality_write_trace(engine):
    sd = _one_write_phase()
    tr = netsim.transformed_write_trace(sd, SHERMAN, NET, CFG)
    rec = Recorder()
    _sim_equal(engine(tr, NET, CFG.n_ms, True),
               engine(tr, NET, CFG.n_ms, True, recorder=rec))
    assert rec.n_segments == 1 and rec.n_verbs == tr.n_verbs


def test_engine_neutrality_clocked_shift_release():
    """Open-loop idiom: a release-gated trace on a carried clock, with
    the recorder riding the clock across split waves."""
    sd = _one_write_phase()
    tr = netsim.transformed_write_trace(sd, SHERMAN, NET, CFG)
    rng = np.random.default_rng(5)
    gated = V.shift_release(tr, np.sort(rng.uniform(0, 5e-5, tr.n_lanes)))
    base = netsim.simulate(gated, NET, CFG.n_ms, True,
                           clock=ServerClock.fresh(CFG.n_ms))
    clock = ServerClock.fresh(CFG.n_ms)
    clock.recorder = Recorder()
    _sim_equal(base, netsim.simulate(gated, NET, CFG.n_ms, True, clock=clock))
    (seg,) = clock.recorder.segments
    assert seg.clocked and seg.t0_ps == 0


@pytest.mark.parametrize("name", [n for n, _ in ABLATION_LADDER])
def test_workload_neutrality_ladder(name):
    spec = get_preset("ycsb-a", **TINY)
    base = run_systems(spec, [name], CFG)[0]
    recs = {}
    on = run_systems(spec, [name], CFG, recorders=recs, tail_k=8)[0]
    _result_equal(base, on)
    assert base.obs == {} and on.obs["verbs"] == recs[name].n_verbs


def test_cluster_and_open_loop_neutrality():
    """Merged cross-CS GLT-chain waves and open-loop admission: both
    planes are bit-identical under recording."""
    spec = get_preset("write-intensive", **TINY)
    base = run_cluster_systems(spec, ["sherman"], n_clients=8, cfg=CFG)[0]
    on = run_cluster_systems(spec, ["sherman"], n_clients=8, cfg=CFG,
                             recorders={}, tail_k=8)[0]
    _result_equal(base, on)

    ol = get_preset("ycsb-a", **TINY).replace(arrival="poisson",
                                              offered_mops=1.0)
    base = run_open_loop_systems(ol, ["sherman"], n_clients=8, cfg=CFG)[0]
    recs = {}
    on = run_open_loop_systems(ol, ["sherman"], n_clients=8, cfg=CFG,
                               recorders=recs, tail_k=8)[0]
    _result_equal(base, on)
    assert all(s.clocked for s in recs["sherman"].segments)


# --------------------------------------------------------------------------
# 2. span conservation
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", [n for n, _ in ABLATION_LADDER])
def test_span_accounting_ladder(name):
    """Per-FIFO busy spans are non-overlapping, reconcile per verb, and
    sum to the simulator's busy time (independently recomputed from the
    recorded traces' grid constants)."""
    spec = get_preset("write-intensive", **TINY)
    recs = {}
    run_systems(spec, [name], CFG, recorders=recs, tail_k=4)
    rec = recs[name]
    acc = span_accounting(rec)
    assert acc["ok"]
    want_nic = np.zeros(acc["n_ms"], np.int64)
    want_atomic = np.zeros(acc["n_ms"], np.int64)
    for seg in rec.segments:
        np.add.at(want_nic, seg.ms, seg.svc_ps)
        cm = seg.kind == V.CAS
        np.add.at(want_atomic, seg.ms[cm],
                  np.full(int(cm.sum()), seg.cas_ps, np.int64))
    assert np.allclose(acc["nic_busy_s"],
                       want_nic / netsim.PS_PER_S, rtol=0, atol=0)
    assert np.allclose(acc["atomic_busy_s"],
                       want_atomic / netsim.PS_PER_S, rtol=0, atol=0)


def test_segments_tile_sim_time():
    """Closed-loop segments sit end-to-end on the engine's accumulated
    ``sim_time_s`` timeline: each capture's t0 equals the counter before
    its phase, and the final horizon matches the final counter."""
    spec = get_preset("ycsb-a", **TINY)
    idx = build_index(SYSTEMS["sherman"], CFG, records=spec.load_records)
    rec = Recorder()
    r = run_workload(idx, spec, system="sherman", recorder=rec, tail_k=4)
    t0s = [s.t0_ps for s in rec.segments]
    assert t0s == sorted(t0s)
    horizon = max(s.t0_ps + s.makespan_ps for s in rec.segments)
    assert horizon / netsim.PS_PER_S == pytest.approx(
        idx.counters["sim_time_s"], rel=1e-9)
    assert r.obs["horizon_s"] == pytest.approx(horizon / netsim.PS_PER_S)


# --------------------------------------------------------------------------
# 3. tail forensics: exact attribution + the HOCL shift
# --------------------------------------------------------------------------

def test_attribution_sums_exactly_top64():
    """Acceptance: for the top-64 slowest ops the four components sum to
    the op's latency with zero integer residual."""
    spec = get_preset("write-intensive", load_records=2_000, ops=512,
                      batch=256, theta=0.99)
    recs = {}
    run_systems(spec, ["sherman", "+on-chip"], CFG, recorders=recs,
                tail_k=64)
    for rec in recs.values():
        rows = attribute_ops(rec, top_k=64)
        assert len(rows) == 64
        for r in rows:
            assert r["residual_ps"] == 0
            assert min(r["nic_queue_us"], r["atomic_ser_us"],
                       r["lock_wait_us"], r["service_us"]) >= 0


def test_hocl_shifts_tail_attribution():
    """The Fig. 10/11 mechanism, per op: enabling HOCL removes the
    per-handover CAS+UNLOCK round trips, so the p99 tail's lock-protocol
    share drops and the NIC/data share (queue + service) rises."""
    spec = get_preset("write-intensive", load_records=2_000, ops=512,
                      batch=256, theta=0.99)
    recs = {}
    run_systems(spec, ["+on-chip", "+hierarchical"], CFG, recorders=recs,
                tail_k=64)
    pre = summarize(recs["+on-chip"], tail_k=64)["tail_attribution"]
    post = summarize(recs["+hierarchical"], tail_k=64)["tail_attribution"]
    assert post["lock_wait_frac"] < pre["lock_wait_frac"]
    assert (post["nic_queue_frac"] + post["service_frac"]
            > pre["nic_queue_frac"] + pre["service_frac"])


def test_flat_rungs_pay_atomic_serialization():
    """Pre-on-chip rungs serialize spin CASes on the software atomic
    unit; the attribution walk must surface that as atomic_ser."""
    spec = get_preset("write-intensive", load_records=2_000, ops=512,
                      batch=256, theta=0.99)
    recs = {}
    run_systems(spec, ["fg+", "sherman"], CFG, recorders=recs, tail_k=64)
    fg = summarize(recs["fg+"], tail_k=64)
    sh = summarize(recs["sherman"], tail_k=64)
    assert fg["tail_attribution"]["atomic_ser_frac"] > 0.05
    assert sh["tail_attribution"]["atomic_ser_frac"] == pytest.approx(0.0)


# --------------------------------------------------------------------------
# 4. export: trace-viewer JSON + derived series
# --------------------------------------------------------------------------

def test_chaos_run_exports_valid_trace(tmp_path):
    """Acceptance: an open-loop-style chaos run (crash + failover on the
    shared timeline) exports a valid Chrome/Perfetto trace with fault
    markers, and the forensic invariants survive the time jump."""
    from repro.chaos import ChaosRunner
    from repro.cluster import build_cluster
    from repro.workloads.spec import FaultEvent, WorkloadSpec

    spec = WorkloadSpec(name="chaos-mix", read=0.3, update=0.3, insert=0.2,
                        delete=0.1, rmw=0.1, load_records=2_000, ops=384,
                        batch=128,
                        faults=(FaultEvent(kind="ms_crash", at_s=2e-4, ms=1),
                                FaultEvent(kind="cs_leave", at_s=4e-4,
                                           cs=2)))
    cl = build_cluster(SHERMAN, CFG, n_clients=8, records=2_000,
                      cache_bytes=4 << 20, sync_rounds=2)
    rec = Recorder()
    cl.recorder = rec
    ChaosRunner(cl, spec, seed=1).run()
    assert [f["kind"] for f in rec.faults] == ["ms_crash", "cs_leave"]
    t0s = [s.t0_ps for s in rec.segments]
    assert t0s == sorted(t0s)          # segments follow the crash jump
    s = summarize(rec, tail_k=16)
    assert s["attr_residual_ps"] == 0 and s["spans_ok"]

    path = tmp_path / "chaos.trace.json"
    write_chrome_trace(rec, str(path))
    doc = json.loads(path.read_text())
    ev = doc["traceEvents"]
    phases = {e["ph"] for e in ev}
    assert {"X", "M", "i", "C"} <= phases
    assert sum(e["ph"] == "i" for e in ev) == 2
    for e in ev:
        if e["ph"] == "X":
            assert e["dur"] >= 0 and e["ts"] >= 0


def test_timeseries_shapes_and_bounds():
    spec = get_preset("write-intensive", **TINY)
    recs = {}
    run_systems(spec, ["sherman"], CFG, recorders=recs, tail_k=4)
    ts = timeseries(recs["sherman"], buckets=32)
    util = np.asarray(ts["nic_util"])
    assert util.shape == (CFG.n_ms, 32)
    assert (util >= 0).all() and (util <= 1 + 1e-9).all()
    assert len(ts["t_s"]) == 32
    assert all(row["lock_verbs"] >= row["chained"] >= 0
               for row in ts["lock_chain"])


def test_summary_is_json_and_in_run_result(tmp_path):
    spec = get_preset("ycsb-a", **TINY)
    recs = {}
    (r,) = run_systems(spec, ["sherman"], CFG, recorders=recs, tail_k=8)
    json.dumps(r.to_dict())
    assert len(r.obs["tail"]) == 8
    assert r.obs["p99_latency_us"] > 0
    assert set(r.obs["attribution"]) >= {
        "nic_queue_frac", "atomic_ser_frac", "lock_wait_frac",
        "service_frac"}


# --------------------------------------------------------------------------
# 5. hypothesis: neutrality + exactness under random release gates
# --------------------------------------------------------------------------

def test_hypothesis_gated_trace_invariants():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    sd = _one_write_phase()

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           span_us=st.floats(0.1, 100.0),
           feat_i=st.integers(0, len(ABLATION_LADDER) - 1),
           clocked=st.booleans())
    def prop(seed, span_us, feat_i, clocked):
        feat = ABLATION_LADDER[feat_i][1]
        tr = netsim.transformed_write_trace(sd, feat, NET, CFG)
        rng = np.random.default_rng(seed)
        gated = V.shift_release(tr, rng.uniform(0, span_us * 1e-6,
                                                tr.n_lanes))
        clock = ServerClock.fresh(CFG.n_ms) if clocked else None
        base = netsim.simulate(gated, NET, CFG.n_ms, feat.onchip,
                               clock=ServerClock.fresh(CFG.n_ms)
                               if clocked else None)
        rec = Recorder()
        _sim_equal(base, netsim.simulate(gated, NET, CFG.n_ms, feat.onchip,
                                         clock=clock, recorder=rec))
        assert span_accounting(rec)["ok"]
        assert all(r["residual_ps"] == 0 for r in attribute_ops(rec))

    prop()
