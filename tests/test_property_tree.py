"""Hypothesis property tests: the batched tree matches the oracle under
arbitrary interleavings of insert/update/delete/lookup/range batches."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import ShermanIndex, TreeConfig, OracleIndex

CFG = TreeConfig(n_ms=2, nodes_per_ms=1024, fanout=8, n_locks_per_ms=512,
                 max_height=7, n_cs=2)

KEYS = st.integers(min_value=0, max_value=2_000)   # small space => collisions
VALS = st.integers(min_value=0, max_value=1 << 20)

op_batch = st.lists(
    st.tuples(st.sampled_from(["insert", "delete"]), KEYS, VALS),
    min_size=1, max_size=48)


@settings(max_examples=25, deadline=None)
@given(st.lists(op_batch, min_size=1, max_size=6), st.randoms())
def test_tree_matches_oracle(batches, rnd):
    idx = ShermanIndex.build(CFG, np.zeros(0, np.int32),
                             np.zeros(0, np.int32))
    oracle = OracleIndex()
    for batch in batches:
        ins_k = [k for op, k, v in batch if op == "insert"]
        ins_v = [v for op, k, v in batch if op == "insert"]
        del_k = [k for op, k, v in batch if op == "delete"]
        if ins_k:
            idx.insert(np.asarray(ins_k), np.asarray(ins_v))
            oracle.insert_batch(ins_k, ins_v)
        if del_k:
            idx.delete(np.asarray(del_k))
            oracle.delete_batch(del_k)
    # full state check
    items = oracle.items()
    probe = np.asarray([k for k, _ in items] + [3_000, 4_000], np.int32)
    got, found = idx.lookup(probe)
    assert found[:len(items)].all()
    assert not found[len(items):].any()
    for (k, v), g in zip(items, got[:len(items)]):
        assert v == g, (k, v, g)
    # ordered iteration equals the oracle (range from 0)
    if items:
        rk, rv, rn = idx.range(np.asarray([0], np.int32),
                               count=min(len(items), 16),
                               max_leaves=600)
        want = items[:min(len(items), 16)]
        gotr = [(int(a), int(b)) for a, b in zip(rk[0][:rn[0]],
                                                 rv[0][:rn[0]])]
        assert gotr == want


@settings(max_examples=15, deadline=None)
@given(st.lists(KEYS, min_size=1, max_size=64, unique=True),
       st.integers(0, 2**31 - 2))
def test_mixed_same_batch_insert_delete(keys, seed):
    """Insert and delete of the same keys inside ONE batch: last op wins."""
    rng = np.random.default_rng(seed)
    idx = ShermanIndex.build(CFG, np.zeros(0, np.int32),
                             np.zeros(0, np.int32))
    oracle = OracleIndex()
    ks = np.asarray(keys, np.int32)
    idx.insert(ks, ks * 2)
    oracle.insert_batch(ks, ks * 2)
    # delete half in a batch that also re-inserts a few afterwards (lane
    # order = oracle application order)
    half = ks[: len(ks) // 2]
    idx.delete(half)
    oracle.delete_batch(half)
    got, found = idx.lookup(ks)
    for i, k in enumerate(ks):
        assert bool(found[i]) == (oracle.lookup(int(k)) is not None)
