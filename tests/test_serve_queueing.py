"""The open-loop serving plane, validated against queueing theory.

Three layers of evidence that the event simulator is a faithful queue:

* **Analytic** — the single-station harness is an M/G/1 queue by
  construction, so its measured mean queueing delay must match the
  Pollaczek–Khinchine formula (with empirical service moments, which
  makes the check exact for both deterministic and exponential
  payloads), and must diverge as utilization approaches 1.
* **Structural** — wave formation is timing-neutral (chunked dispatch
  against the carried :class:`ServerClock` is bit-identical to one-shot
  replay), both replay engines agree verb-for-verb with a carried clock,
  and the sojourn identity ``sojourn = wait + service + RTT`` holds to
  the picosecond grid.
* **Differential** — with every arrival at t=0 the open-loop cluster
  serving path reproduces the closed-loop scheduler *tick for tick*:
  same trace digests, same counters, same per-node totals.

Arrival-generator properties (seeded determinism, Poisson mean gap,
bursty CV dominance, monotone int64 grid, overflow guard) run as plain
deterministic checks; richer randomized versions run when Hypothesis is
installed and skip cleanly when it is not (no new dependencies).
"""
import numpy as np
import pytest

from repro.core import TreeConfig, netsim
from repro.core.netsim import PS_PER_S, SHERMAN, NetConfig
from repro.serve import (bursty_arrivals, diurnal_arrivals, make_arrivals,
                         poisson_arrivals, simulate_station)
from repro.workloads.spec import get_preset

#: Fat RTT relative to service: widens the wavefront engine's horizon
#: (fewer host waves => fast tests) without touching queueing — waits
#: are set by NIC occupancy, not by the completion round trip.
NET = NetConfig(rtt_s=4e-5)
SVC_BYTES = 12_500            # exactly 1 us of NIC occupancy under NET
SVC_S = max(1.0 / NET.nic_iops_small, SVC_BYTES / NET.nic_bw_Bps)
N_PK = 20_000                 # arrivals per analytic validation run


def _pk_wait(arr_ps: np.ndarray, service_s: np.ndarray) -> float:
    """Pollaczek–Khinchine mean queueing delay Wq = λE[S²] / 2(1−ρ),
    with λ and the service moments taken *empirically* from the realized
    run — exact for any M/G/1, no distributional assumption."""
    lam = (arr_ps.size - 1) / ((arr_ps[-1] - arr_ps[0]) / PS_PER_S)
    rho = lam * service_s.mean()
    assert rho < 1.0
    return lam * np.mean(service_s ** 2) / (2.0 * (1.0 - rho))


# --------------------------------------------------------------------------
# analytic: Pollaczek–Khinchine
# --------------------------------------------------------------------------

@pytest.mark.parametrize("rho", [0.2, 0.5, 0.8])
def test_md1_pollaczek_khinchine(rho):
    """Deterministic payload => M/D/1: the simulated mean queueing delay
    matches the P-K closed form within 15% at every utilization."""
    arr = poisson_arrivals(rho / SVC_S, N_PK, seed=3)
    sim = simulate_station(arr / PS_PER_S, SVC_BYTES, NET, n_ms=1)
    wq = _pk_wait(arr, sim["service_s"])
    assert sim["wait_s"].mean() == pytest.approx(wq, rel=0.15)


@pytest.mark.parametrize("rho", [0.2, 0.5, 0.8])
def test_mg1_exponential_pollaczek_khinchine(rho):
    """Exponential-ish payloads (the M/M/1 shape, floored by the per-verb
    IOPS cost): empirical-moment P-K still pins the simulator — the
    queue does not care about the service distribution beyond its first
    two moments, and neither does the formula."""
    rng = np.random.default_rng(11)
    nbytes = np.maximum(1, rng.exponential(SVC_BYTES, N_PK)).astype(np.int64)
    mean_svc = np.maximum(1.0 / NET.nic_iops_small,
                          nbytes / NET.nic_bw_Bps).mean()
    arr = poisson_arrivals(rho / mean_svc, N_PK, seed=5)
    sim = simulate_station(arr / PS_PER_S, nbytes, NET, n_ms=1)
    wq = _pk_wait(arr, sim["service_s"])
    assert sim["wait_s"].mean() == pytest.approx(wq, rel=0.15)
    # M/M/1-vs-M/D/1 shape: variable service queues strictly worse than
    # deterministic service at equal utilization (E[S^2] dominance)
    det = simulate_station(
        poisson_arrivals(rho / SVC_S, N_PK, seed=5) / PS_PER_S,
        SVC_BYTES, NET, n_ms=1)
    assert sim["wait_s"].mean() > det["wait_s"].mean()


def test_queueing_diverges_near_saturation():
    """Wq must blow up as rho -> 1 (the hockey stick): the simulated mean
    wait at rho=0.95 is several times the rho=0.8 wait, and both exceed
    the rho=0.5 wait."""
    waits = {}
    for rho in (0.5, 0.8, 0.95):
        arr = poisson_arrivals(rho / SVC_S, N_PK, seed=9)
        sim = simulate_station(arr / PS_PER_S, SVC_BYTES, NET, n_ms=1)
        waits[rho] = sim["wait_s"].mean()
    assert waits[0.8] > 2.0 * waits[0.5]
    assert waits[0.95] > 3.0 * waits[0.8]


# --------------------------------------------------------------------------
# structural: chunking invariance, engine agreement, sojourn identity
# --------------------------------------------------------------------------

def test_wave_chunking_is_timing_neutral():
    """Dispatching the stream in host waves against the carried
    ServerClock yields bit-identical completions and waits to one-shot
    replay — wave formation is an execution-granularity knob only."""
    arr = poisson_arrivals(0.7 / SVC_S, 5_000, seed=7) / PS_PER_S
    one = simulate_station(arr, SVC_BYTES, NET, n_ms=2)
    for chunk in (1_024, 333, 1):
        waved = simulate_station(arr, SVC_BYTES, NET, n_ms=2, chunk=chunk)
        assert np.array_equal(one["comp_s"], waved["comp_s"]), chunk
        assert np.array_equal(one["wait_s"], waved["wait_s"]), chunk


def test_replay_engines_agree_with_carried_clock():
    """The vectorized wavefront engine and the heapq reference are pinned
    verb-for-verb on release-gated traces with a carried clock."""
    arr = poisson_arrivals(0.8 / SVC_S, 2_000, seed=13) / PS_PER_S
    rng = np.random.default_rng(13)
    nbytes = np.maximum(1, rng.exponential(SVC_BYTES, 2_000)).astype(np.int64)
    wf = simulate_station(arr, nbytes, NET, n_ms=2, chunk=512)
    ref = simulate_station(arr, nbytes, NET, n_ms=2, chunk=512, engine="ref")
    assert np.array_equal(wf["comp_s"], ref["comp_s"])
    assert np.array_equal(wf["wait_s"], ref["wait_s"])


def test_sojourn_identity():
    """Per op: sojourn == queueing wait + service + RTT, on the ps grid."""
    arr = poisson_arrivals(0.6 / SVC_S, 3_000, seed=17) / PS_PER_S
    sim = simulate_station(arr, SVC_BYTES, NET, n_ms=1)
    lhs = sim["sojourn_s"]
    rhs = sim["wait_s"] + sim["service_s"] + sim["rtt_s"]
    assert np.allclose(lhs, rhs, rtol=0, atol=1e-12)
    assert (sim["wait_s"] >= 0).all()


# --------------------------------------------------------------------------
# arrival-generator properties (deterministic; Hypothesis versions below)
# --------------------------------------------------------------------------

GEN_CASES = [
    ("poisson", {}),
    ("bursty", {}),
    ("diurnal", {}),
]


@pytest.mark.parametrize("kind,kw", GEN_CASES)
def test_generators_deterministic_monotone_int64(kind, kw):
    """Same seed => identical stream; different seed => different stream;
    timestamps are non-decreasing int64 on the ps grid."""
    a = make_arrivals(kind, 2e6, 4_096, seed=42, **kw)
    b = make_arrivals(kind, 2e6, 4_096, seed=42, **kw)
    c = make_arrivals(kind, 2e6, 4_096, seed=43, **kw)
    assert a.dtype == np.int64 and a.size == 4_096
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
    assert (np.diff(a) >= 0).all()


@pytest.mark.parametrize("kind,kw", GEN_CASES)
def test_generators_hit_requested_mean_rate(kind, kw):
    """Mean interarrival gap ~= 1/rate for every process (all three
    normalize to the requested mean rate)."""
    rate = 1e6
    arr = make_arrivals(kind, rate, 60_000, seed=1, **kw)
    mean_gap_s = float(np.diff(arr).mean()) / PS_PER_S
    assert mean_gap_s == pytest.approx(1.0 / rate, rel=0.05)


def test_bursty_cv_exceeds_poisson():
    """Interarrival coefficient of variation: the MMPP must be strictly
    burstier than Poisson (CV > 1) — the defining property."""
    def cv(arr):
        gaps = np.diff(arr).astype(np.float64)
        return gaps.std() / gaps.mean()
    p = poisson_arrivals(1e6, 60_000, seed=2)
    b = bursty_arrivals(1e6, 60_000, seed=2)
    assert cv(b) > 1.15 * cv(p)
    assert cv(p) == pytest.approx(1.0, rel=0.05)   # Poisson: CV = 1


def test_paper_scale_rates_do_not_overflow():
    """Paper-scale offered loads (tens of Mops over millions of ops) stay
    far inside the int64 ps grid; an absurd horizon raises instead of
    silently wrapping."""
    arr = poisson_arrivals(50e6, 200_000, seed=0)
    assert arr[-1] < np.int64(1) << 62
    assert (np.diff(arr) >= 0).all()
    with pytest.raises(OverflowError):
        poisson_arrivals(1e-6, 8, seed=0)   # ~ one op per 11.5 days


def test_generator_argument_validation():
    with pytest.raises(ValueError):
        poisson_arrivals(0.0, 10)
    with pytest.raises(ValueError):
        bursty_arrivals(1e6, 10, burst_factor=12.0, burst_frac=0.2)
    with pytest.raises(ValueError):
        diurnal_arrivals(1e6, 10, peak=2.5)
    with pytest.raises(ValueError):
        make_arrivals("sawtooth", 1e6, 10)


# --------------------------------------------------------------------------
# Hypothesis property tests (skip cleanly when hypothesis is absent)
# --------------------------------------------------------------------------

def _hyp():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import strategies as st
    return hyp, st


def test_hypothesis_generator_properties():
    """Randomized generator properties over (kind, rate, n, seed): seeded
    determinism, monotone non-decreasing int64 grid, and mean-rate
    normalization — the same invariants as the deterministic checks, but
    over a sampled parameter space."""
    hyp, st = _hyp()

    @hyp.settings(max_examples=25, deadline=None)
    @hyp.given(kind=st.sampled_from(("poisson", "bursty", "diurnal")),
               rate=st.floats(1e4, 5e7), n=st.integers(64, 4_096),
               seed=st.integers(0, 2 ** 31))
    def check(kind, rate, n, seed):
        a = make_arrivals(kind, rate, n, seed=seed)
        b = make_arrivals(kind, rate, n, seed=seed)
        assert a.dtype == np.int64
        assert np.array_equal(a, b)
        assert (np.diff(a) >= 0).all()
        assert a[-1] < np.int64(1) << 62

    check()


def test_hypothesis_poisson_mean_gap():
    """E[gap] -> 1/λ for Poisson at any sampled rate (LLN tolerance)."""
    hyp, st = _hyp()

    @hyp.settings(max_examples=10, deadline=None)
    @hyp.given(rate=st.floats(1e5, 2e7), seed=st.integers(0, 2 ** 16))
    def check(rate, seed):
        arr = poisson_arrivals(rate, 30_000, seed=seed)
        mean_gap = float(np.diff(arr).mean()) / PS_PER_S
        assert mean_gap == pytest.approx(1.0 / rate, rel=0.10)

    check()


def test_hypothesis_bursty_cv_dominance():
    """Bursty CV strictly exceeds Poisson's for any valid MMPP shape."""
    hyp, st = _hyp()

    @hyp.settings(max_examples=10, deadline=None)
    @hyp.given(factor=st.floats(3.0, 9.0), frac=st.floats(0.05, 0.1),
               seed=st.integers(0, 2 ** 16))
    def check(factor, frac, seed):
        def cv(arr):
            g = np.diff(arr).astype(np.float64)
            return g.std() / g.mean()
        b = bursty_arrivals(1e6, 40_000, seed=seed, burst_factor=factor,
                            burst_frac=frac)
        p = poisson_arrivals(1e6, 40_000, seed=seed)
        assert cv(b) > cv(p)

    check()


# --------------------------------------------------------------------------
# differential: t=0 open loop == closed loop, tick for tick
# --------------------------------------------------------------------------

CFG_CL = TreeConfig(n_ms=2, nodes_per_ms=1024, fanout=8, n_locks_per_ms=512,
                    max_height=6, n_cs=4)
TINY = dict(load_records=2_000, ops=256, batch=128)


def _mixed_spec():
    """Every op kind at once — exercises the full materialization order
    (scan, read, rmw, update, delete, insert) and insert-driven
    record-space growth."""
    from repro.workloads.spec import WorkloadSpec
    return WorkloadSpec(name="mixed", read=0.3, insert=0.2, update=0.2,
                        delete=0.1, scan=0.1, rmw=0.1, **TINY)


@pytest.mark.parametrize("spec_fn", [lambda: get_preset("ycsb-a", **TINY),
                                     _mixed_spec],
                         ids=["ycsb-a", "all-kinds"])
def test_open_loop_t0_reproduces_closed_loop(spec_fn):
    """With every arrival stamped at t=0, the serving plane must execute
    the closed-loop scheduler's exact program: identical op streams,
    identical merged-trace digests in identical order, identical
    counters (except the deliberately redefined ``sim_time_s``),
    identical per-node totals and doorbell samples."""
    from repro.cluster import build_cluster, run_cluster
    from repro.serve import run_open_loop
    spec = spec_fn()

    closed = build_cluster(SHERMAN, CFG_CL, n_clients=8,
                           records=TINY["load_records"], seed=0)
    closed.record_traces()
    done_c, ops_c = run_cluster(closed, spec, seed=1, keyspace=1 << 20)

    served = build_cluster(SHERMAN, CFG_CL, n_clients=8,
                           records=TINY["load_records"], seed=0)
    served.record_traces()
    done_o, ops_o, info = run_open_loop(served, spec, seed=1,
                                        keyspace=1 << 20)

    assert done_o == done_c and ops_o == ops_c
    assert served.trace_log == closed.trace_log      # tick-for-tick
    kc = {k: v for k, v in closed.combined_counters().items()
          if k != "sim_time_s"}
    ko = {k: v for k, v in served.combined_counters().items()
          if k != "sim_time_s"}
    assert ko == kc
    assert served.node_totals() == closed.node_totals()
    assert np.array_equal(np.concatenate(served.doorbells_write),
                          np.concatenate(closed.doorbells_write))
    assert info["last_arrival_s"] == 0.0
    # the open horizon is an absolute clock, not a sum of makespans —
    # overlapping wave tails make it at most the closed-loop sum
    assert 0 < served.counters["sim_time_s"] <= closed.counters["sim_time_s"]


def test_open_loop_poisson_end_to_end():
    """RunResult sanity on a real Poisson run: queueing is reported
    separately from service, the sojourn exceeds its parts, attainment
    and sustained fractions are proper fractions, and offered load is
    echoed back."""
    from repro.workloads.engine import (run_cluster_workload,
                                        run_open_loop_workload)
    base = get_preset("write-intensive", **TINY)
    cal = run_cluster_workload(base, SHERMAN, n_clients=8, cfg=CFG_CL,
                               seed=1, system="sherman")
    rate = 0.6 * cal.mops
    spec = base.replace(arrival="poisson", offered_mops=rate)
    r = run_open_loop_workload(spec, SHERMAN, n_clients=8, cfg=CFG_CL,
                               seed=1, system="sherman",
                               slo_us=4 * cal.p99_us)
    assert r.arrival == "poisson"
    assert r.offered_mops == pytest.approx(rate)
    assert r.n_ops >= base.ops and r.mops > 0
    assert r.queue_mean_us >= 0 and r.service_mean_us > 0
    assert r.p50_us > r.queue_p50_us          # sojourn > queueing share
    assert 0 < r.slo_attainment <= 1
    assert 0 < r.sustained_frac <= 1
    assert r.conservation_ok
    import json
    json.dumps(r.to_dict())


def test_overload_degrades_gracefully():
    """Past the knee the serving plane must not report a sustained run:
    a heavily overloaded offered rate yields sustained_frac < 1 and more
    queueing than a lightly loaded run."""
    from repro.workloads.engine import (run_cluster_workload,
                                       run_open_loop_workload)
    base = get_preset("write-intensive", **TINY)
    cal = run_cluster_workload(base, SHERMAN, n_clients=8, cfg=CFG_CL,
                               seed=1, system="sherman")
    light = run_open_loop_workload(
        base.replace(arrival="poisson", offered_mops=0.3 * cal.mops),
        SHERMAN, n_clients=8, cfg=CFG_CL, seed=1, system="sherman")
    heavy = run_open_loop_workload(
        base.replace(arrival="poisson", offered_mops=4.0 * cal.mops),
        SHERMAN, n_clients=8, cfg=CFG_CL, seed=1, system="sherman")
    assert heavy.sustained_frac < light.sustained_frac
    assert heavy.sustained_frac < 1.0
    assert heavy.queue_mean_us > light.queue_mean_us


def test_spec_validates_open_loop_fields():
    base = get_preset("ycsb-a", **TINY)
    with pytest.raises(ValueError):
        base.replace(arrival="poisson")            # no offered rate
    with pytest.raises(ValueError):
        base.replace(arrival="sawtooth", offered_mops=1.0)
    with pytest.raises(ValueError):
        base.replace(arrival="bursty", offered_mops=1.0,
                     burst_factor=20.0, burst_frac=0.2)
    with pytest.raises(ValueError):
        base.replace(arrival="diurnal", offered_mops=1.0, diurnal_peak=3.0)
    ok = base.replace(arrival="poisson", offered_mops=1.5)
    assert ok.offered_mops == 1.5


# --------------------------------------------------------------------------
# spliced arrival streams (chaos-plane skew/storm windows)
# --------------------------------------------------------------------------

def test_spliced_arrivals_rate_change_mid_stream():
    """A rate change mid-wave: the spliced stream is one monotone int64
    series whose empirical gap mean tracks each phase's rate."""
    from repro.serve import spliced_arrivals
    ts = spliced_arrivals([("poisson", 1e5, 2_000),
                           ("poisson", 8e5, 2_000)], seed=3)
    assert ts.dtype == np.int64 and ts.size == 4_000
    assert (np.diff(ts) >= 0).all()
    gaps_a = np.diff(ts[:2_000]) / 1e12
    gaps_b = np.diff(ts[2_000:]) / 1e12
    assert gaps_a.mean() == pytest.approx(1e-5, rel=0.15)
    assert gaps_b.mean() == pytest.approx(1.25e-6, rel=0.15)
    # the high-rate phase starts where the low-rate one ended
    assert ts[2_000] >= ts[1_999]


def test_spliced_arrivals_zero_length_phases():
    """Empty phases contribute nothing and never reseed their
    neighbours: dropping them entirely gives the identical stream."""
    from repro.serve import spliced_arrivals
    with_gaps = spliced_arrivals(
        [("poisson", 2e5, 0), ("poisson", 4e5, 512),
         ("bursty", 4e5, 0), ("poisson", 4e5, 0)], seed=9)
    plain = spliced_arrivals(
        [("poisson", 2e5, 0), ("poisson", 4e5, 512)], seed=9)
    np.testing.assert_array_equal(with_gaps, plain)
    assert spliced_arrivals([], seed=9).size == 0
    assert spliced_arrivals([("poisson", 1e5, 0)], seed=9).size == 0


def test_spliced_arrivals_deterministic_and_phase_independent():
    """Same (phases, seed) => identical splice; each phase draws from
    its own child seed, so editing one phase leaves the *first* phase's
    arrivals untouched (prefix stability) and two phases at the same
    rate still draw different streams."""
    from repro.serve import spliced_arrivals
    phases = [("poisson", 3e5, 256), ("diurnal", 6e5, 256),
              ("poisson", 3e5, 256)]
    a = spliced_arrivals(phases, seed=11)
    b = spliced_arrivals(phases, seed=11)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, spliced_arrivals(phases, seed=12))
    # prefix stability under a later-phase edit
    edited = spliced_arrivals(
        [("poisson", 3e5, 256), ("bursty", 9e5, 64)], seed=11)
    np.testing.assert_array_equal(a[:256], edited[:256])
    # same kind+rate in two positions != same draw
    twice = spliced_arrivals(
        [("poisson", 3e5, 256), ("poisson", 3e5, 256)], seed=11)
    assert not np.array_equal(np.diff(twice[:256]), np.diff(twice[256:]))
