"""Sharding rules: every sharded dim divides the axis, for all 10 archs."""
import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ALL_ARCHS, get
from repro.launch import shapes as shp
from repro.models.registry import build
from repro.parallel import sharding as sh


@dataclasses.dataclass
class FakeMesh:
    shape: dict
    axis_names: tuple


MESH1 = FakeMesh({"data": 16, "model": 16}, ("data", "model"))
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16},
                 ("pod", "data", "model"))


def check_divisibility(spec_tree, shape_tree, mesh):
    specs = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, P))
    leaves = jax.tree_util.tree_leaves(shape_tree)
    assert len(specs) == len(leaves)
    for sp, leaf in zip(specs, leaves):
        shape = np.shape(leaf) if not hasattr(leaf, "shape") else leaf.shape
        for dim, axes in enumerate(sp):
            if axes is None:
                continue
            axes = (axes,) if isinstance(axes, str) else axes
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert shape[dim] % size == 0, (sp, shape, dim)


@pytest.mark.parametrize("name", ALL_ARCHS)
@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["single", "multi"])
def test_param_specs_divisible(name, mesh):
    api = build(get(name))
    params = shp.params_specs(api)
    specs = sh.params_pspecs(params, mesh)
    check_divisibility(specs, params, mesh)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_decode_state_specs_divisible(name):
    cfg = get(name)
    api = build(cfg)
    params = shp.params_specs(api)
    for shape_name in ("decode_32k", "long_500k"):
        shape = shp.SHAPES[shape_name]
        ok, _ = shp.cell_supported(cfg, shape)
        if not ok:
            continue
        st = shp.decode_state_specs(api, params, shape)
        specs = sh.decode_state_pspecs(st, MESH1)
        check_divisibility(specs, st, MESH1)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_batch_specs(name):
    cfg = get(name)
    for shape in shp.SHAPES.values():
        ok, _ = shp.cell_supported(cfg, shape)
        if not ok:
            continue
        b = shp.batch_specs(cfg, shape)
        specs = sh.batch_pspecs(b, MESH2)
        check_divisibility(specs, b, MESH2)


def test_attention_fallback_when_heads_not_divisible():
    """40 q-heads can't split 16 ways: wq must fall back to d_model."""
    api = build(get("llama4_scout_17b_a16e"))
    params = shp.params_specs(api)
    names = jax.tree_util.tree_leaves(sh.name_tree(params))
    specs = jax.tree_util.tree_leaves(
        sh.params_pspecs(params, MESH1),
        is_leaf=lambda x: isinstance(x, P))
    by_name = dict(zip(names, specs))
    wq = [v for k, v in by_name.items() if k.endswith("attn.wq")][0]
    # [L, D, H=40, hd=128]: D (index 1) sharded, H untouched
    assert wq[1] == "model" and wq[2] is None


def test_moe_expert_sharding_llama4_vs_qwen():
    """16 experts shard over model; 60 experts fall back to per-expert FF."""
    a1 = build(get("llama4_scout_17b_a16e"))
    a2 = build(get("qwen2_moe_a2_7b"))
    for api, expect_expert in ((a1, True), (a2, False)):
        params = shp.params_specs(api)
        names = jax.tree_util.tree_leaves(sh.name_tree(params))
        specs = jax.tree_util.tree_leaves(
            sh.params_pspecs(params, MESH1),
            is_leaf=lambda x: isinstance(x, P))
        by_name = dict(zip(names, specs))
        wg = [v for k, v in by_name.items()
              if k.endswith("moe.w_gate")][0]
        if expect_expert:
            assert wg[1] == "model"          # [L, E, D, F] E sharded
        else:
            assert wg[1] is None and wg[3] == "model"
