"""End-to-end behaviour tests: the index under a YCSB mix, the serving
integration, and the netsim reproduction invariants."""
import numpy as np

from repro.core import (FG_PLUS, SHERMAN, OracleIndex, ShermanIndex,
                        TreeConfig)

CFG = TreeConfig(n_ms=4, nodes_per_ms=1024, fanout=16, n_locks_per_ms=1024,
                 max_height=7, n_cs=4)


def _ycsb(idx, oracle, rng, n_batches=6, batch=256, skew_hot=64,
          read_frac=0.5):
    for _ in range(n_batches):
        hot = rng.integers(0, skew_hot, batch // 2)
        cold = rng.integers(0, 1 << 18, batch - batch // 2)
        keys = np.concatenate([hot, cold]).astype(np.int32)
        rng.shuffle(keys)
        nr = int(read_frac * batch)
        idx.lookup(keys[:nr])
        vals = rng.integers(0, 1 << 20, batch - nr).astype(np.int32)
        idx.insert(keys[nr:], vals)
        oracle.insert_batch(keys[nr:], vals)


def test_ycsb_mix_end_to_end():
    rng = np.random.default_rng(11)
    base = rng.choice(1 << 18, size=5_000, replace=False)
    idx = ShermanIndex.build(CFG, base, base * 7, features=SHERMAN)
    oracle = OracleIndex()
    oracle.insert_batch(base, base * 7)
    _ycsb(idx, oracle, rng)
    items = oracle.items()
    keys = np.asarray([k for k, _ in items[:2000]])
    want = np.asarray([v for _, v in items[:2000]])
    got, found = idx.lookup(keys)
    assert found.all()
    assert (got == want).all()
    assert idx.counters["handovers"] > 0          # skew exercised HOCL
    assert idx.throughput_mops() > 0


def test_sherman_beats_fg_on_skewed_writes():
    """The paper's headline: order-of-magnitude gap under skewed writes."""
    rng = np.random.default_rng(12)
    base = rng.choice(1 << 18, size=5_000, replace=False)
    results = {}
    for name, feat in (("fg", FG_PLUS), ("sherman", SHERMAN)):
        idx = ShermanIndex.build(CFG, base, base, features=feat)
        hot = rng.integers(0, 32, size=2_048).astype(np.int32)
        idx.insert(hot, hot)
        results[name] = (idx.throughput_mops(),
                         idx.latency_percentiles()[99])
    assert results["sherman"][0] > 5 * results["fg"][0]
    assert results["sherman"][1] < results["fg"][1] / 5


def test_write_bytes_two_level_versions():
    """§5.5.3: non-split writes move ~entry_bytes, not node_bytes."""
    rng = np.random.default_rng(13)
    base = rng.choice(1 << 18, size=5_000, replace=False)
    idx = ShermanIndex.build(CFG, base, base, features=SHERMAN)
    keys = base[:512].astype(np.int32)            # updates: no splits
    idx.insert(keys, keys)
    wb = np.concatenate(idx.write_bytes)
    assert np.median(wb) == CFG.entry_bytes       # 17B with 8B keys/values
    fg = ShermanIndex.build(CFG, base, base, features=FG_PLUS)
    fg.insert(keys, keys)
    assert np.median(np.concatenate(fg.write_bytes)) == CFG.node_bytes


def test_paged_kv_page_table_roundtrip():
    """The serving integration: (seq, page) -> slot mappings survive a
    full admit/lookup/evict cycle (examples/serve_paged.py in miniature)."""
    table = ShermanIndex.build(CFG, np.zeros(0, np.int32),
                               np.zeros(0, np.int32))
    keys = np.asarray([s * 4096 + p for s in range(8) for p in range(4)],
                      np.int32)
    slots = np.arange(len(keys), dtype=np.int32)
    table.insert(keys, slots)
    got, found = table.lookup(keys)
    assert found.all() and (got == slots).all()
    # evict sequence 3 via ordered range scan
    rk, rv, rn = table.range(np.asarray([3 * 4096], np.int32), count=4,
                             max_leaves=16)
    mine = [int(k) for k in rk[0][:rn[0]] if k // 4096 == 3]
    assert len(mine) == 4
    table.delete(np.asarray(mine, np.int32))
    _, found = table.lookup(np.asarray(mine, np.int32))
    assert not found.any()
