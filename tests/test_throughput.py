"""PR 5 shape-stability + replay-equivalence regression tests.

Two planes are pinned here:

* **Compile stability** — the bucketed dispatch discipline
  (:func:`repro.core.api.bucket_size`, fixed ``REPAIR_CAP``) means a
  mixed YCSB workload compiles each jitted entry point once per bucket:
  a bounded count on the first pass, *zero* fresh XLA compilations on a
  repeat pass over the same spec.
* **Replay equivalence** — the vectorized wavefront
  :func:`repro.core.netsim.simulate` must reproduce the reference heapq
  loop :func:`repro.core.netsim.simulate_ref` tick-for-tick (both run on
  the shared integer ps grid) on real write/read/merged-cluster traces
  across the whole ablation ladder, seeded and under hypothesis.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import netsim, verbs as V, write
from repro.core.api import write_stats_dict
from repro.core.netsim import (ABLATION_LADDER, FG_PLUS, SHERMAN,
                               NetConfig)
from repro.core.tree import TreeConfig, bulkload
from repro.workloads import get_preset, run_workload, build_index, SYSTEMS
from repro.workloads.jitstats import count_compiles

CFG = TreeConfig(n_ms=2, nodes_per_ms=1024, fanout=8, n_locks_per_ms=512,
                 max_height=6, n_cs=4)
NET = NetConfig()


# --------------------------------------------------------------------------
# compile stability
# --------------------------------------------------------------------------

def test_mixed_workload_compiles_once_per_bucket():
    """A mixed YCSB run compiles a bounded set of shapes; running the
    same spec again — fresh index, same bucketed shapes — compiles
    nothing new.  This is the regression guard for the shape churn that
    used to recompile every op-mix batch size and repair-queue resize."""
    spec = get_preset("ycsb-d", load_records=2_000, ops=512, batch=128)
    idx = build_index(SYSTEMS["sherman"], CFG, records=spec.load_records)
    with count_compiles() as first:
        run_workload(idx, spec, seed=1)
    if not first.available:
        pytest.skip("compile counter unavailable on this jax")
    # one compile per (entry point, bucket); a mixed 4-kind workload
    # stays far below the old one-compile-per-batch churn
    assert 0 < first.count <= 16, first.count
    idx2 = build_index(SYSTEMS["sherman"], CFG, records=spec.load_records)
    with count_compiles() as second:
        run_workload(idx2, spec, seed=2)
    assert second.count == 0, second.count


def test_bucketing_pads_and_slices_correctly():
    """Odd batch sizes round-trip through the padded dispatch: results
    are sliced back to the caller's length and padding lanes never leak
    into counters."""
    from repro.core import ShermanIndex
    rng = np.random.default_rng(0)
    base = rng.choice(50_000, size=1_000, replace=False)
    idx = ShermanIndex.build(CFG, base, base)
    for n in (1, 3, 17, 100):
        got, found = idx.lookup(base[:n].astype(np.int32))
        assert got.shape == (n,) and found.shape == (n,)
        assert found.all() and (got == base[:n]).all()
    c0 = dict(idx.counters)
    keys = base[:37].astype(np.int32)
    idx.insert(keys, keys + 1)
    assert idx.counters["write_ops"] - c0["write_ops"] == 37
    got, found = idx.lookup(keys)
    assert found.all() and (got == keys + 1).all()
    k, v, cnt = idx.range(base[:5].astype(np.int32), count=4)
    assert k.shape == (5, 4) and cnt.shape == (5,)


def test_repair_queue_capacity_is_batch_independent():
    """The driver-owned repair queue keeps its fixed capacity across
    batch sizes (no shape churn), and dense split-heavy inserts still
    drain to a correct tree."""
    from repro.core import ShermanIndex
    from repro.core.api import REPAIR_CAP
    idx = ShermanIndex.build(CFG, np.arange(0, 640, 10), np.arange(64))
    assert idx._repair.valid.shape == (REPAIR_CAP,)
    keys = np.arange(0, 512, 2).astype(np.int32)
    idx.insert(keys, keys)
    assert idx._repair.valid.shape == (REPAIR_CAP,)
    assert idx._repair_backlog == 0
    assert idx.counters["leaf_splits"] > 0
    got, found = idx.lookup(keys)
    assert found.all() and (got == keys).all()


# --------------------------------------------------------------------------
# replay equivalence: simulate == simulate_ref, tick for tick
# --------------------------------------------------------------------------

def _phase_sd(n, seed, cs_spread=True, hot=40):
    """One real write phase over a seeded tree (hot + fresh keys =>
    contention, handover chains, splits)."""
    rng = np.random.default_rng(seed)
    base = rng.choice(20_000, size=600, replace=False)
    st = bulkload(CFG, base, base)
    hotk = rng.integers(0, hot, size=n // 2)
    new = rng.choice(np.setdiff1d(np.arange(20_000), base), size=n - n // 2,
                     replace=False)
    keys = jnp.asarray(np.concatenate([hotk, new]), jnp.int32)
    cs = jnp.asarray(np.arange(n) % (CFG.n_cs if cs_spread else 1),
                     jnp.int32)
    _, _, stats, _ = write.write_phase(CFG, st, keys, jnp.ones_like(keys),
                                       jnp.zeros((n,), bool),
                                       jnp.ones((n,), bool), cs)
    return write_stats_dict(stats, np.ones(n, bool), np.zeros(n, bool),
                            int(st.height))


def _assert_sim_equal(tr, onchip):
    ref = netsim.simulate_ref(tr, NET, CFG.n_ms, onchip)
    vec = netsim.simulate(tr, NET, CFG.n_ms, onchip)
    np.testing.assert_allclose(vec["latency_s"], ref["latency_s"],
                               rtol=1e-9, atol=0)
    assert vec["makespan_s"] == pytest.approx(ref["makespan_s"],
                                              rel=1e-9)
    for k in ("msgs", "verbs", "cas_msgs", "doorbells"):
        assert vec[k] == ref[k]
    assert vec["bytes"] == pytest.approx(ref["bytes"])
    np.testing.assert_array_equal(vec["lane_doorbells"],
                                  ref["lane_doorbells"])


@pytest.mark.parametrize("name,feat", ABLATION_LADDER)
def test_simulate_matches_ref_across_ablation_ladder(name, feat):
    """Every ablation rung's transformed write trace replays identically
    through the wavefront and the reference heap (spin storms, handover
    chains, combined doorbells — all of it)."""
    sd = _phase_sd(96, seed=11)
    tr = netsim.transformed_write_trace(sd, feat, NET, CFG)
    _assert_sim_equal(tr, feat.onchip)


def test_simulate_matches_ref_on_read_and_maintenance_traces():
    rng = np.random.default_rng(5)
    reads = rng.integers(1, 5, size=200).astype(np.int64)
    tr = V.read_phase_trace(reads, rng.integers(0, CFG.n_ms, 200),
                            CFG.n_ms, CFG.node_bytes)
    _assert_sim_equal(tr, True)
    tr = V.maintenance_trace(37, 91, CFG.n_ms, CFG.node_bytes, 128)
    _assert_sim_equal(tr, False)


@pytest.mark.parametrize("feat", [SHERMAN, FG_PLUS],
                         ids=["sherman", "fg+"])
def test_simulate_matches_ref_on_merged_cluster_traces(feat):
    """Merged multi-CS traces — including the cross-CS GLT lock chains
    `merge_traces` injects — replay identically."""
    traces = []
    for cs in range(3):
        sd = _phase_sd(24, seed=100 + cs)
        traces.append(netsim.transformed_write_trace(sd, feat, NET, CFG))
    merged = V.merge_traces(traces, glt_chain=True)
    locks = np.nonzero(merged.role == V.LOCK)[0]
    assert (merged.dep2[locks] >= 0).any()       # chains actually present
    _assert_sim_equal(merged, feat.onchip)


def test_property_simulate_equivalence():
    """Hypothesis: arbitrary phase sizes / skews / ladder rungs replay
    identically through both engines."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.integers(min_value=2, max_value=64),
           st.integers(min_value=0, max_value=2 ** 31 - 1),
           st.integers(min_value=2, max_value=200),
           st.sampled_from([feat for _, feat in ABLATION_LADDER]))
    def inner(n, seed, hot, feat):
        sd = _phase_sd(n, seed=seed, hot=hot)
        tr = netsim.transformed_write_trace(sd, feat, NET, CFG)
        _assert_sim_equal(tr, feat.onchip)

    inner()


def test_drain_repairs_syncs_in_batches():
    """Satellite: the repair drain reads the backlog from the write
    phase's stats (no device sync when the queue is empty) and the
    jitted step exposes the pending count for k-batched host checks."""
    from repro.core.api import _jit_repair
    from repro.core.write import RepairQueue
    from repro.core.api import REPAIR_CAP
    st = bulkload(CFG, np.arange(0, 4_000, 7), np.arange(572))
    out = _jit_repair(CFG, st, RepairQueue.empty(REPAIR_CAP))
    assert len(out) == 5                       # ..., ni, nr, pending
    assert int(out[4]) == 0
