"""The unified workload engine: YCSB A-F end-to-end, key generators,
result accounting, CLI + BENCH json emission, and the benchmark shim."""
import json

import numpy as np
import pytest

from repro.core import TreeConfig
from repro.workloads import (PRESETS, SYSTEMS, WorkloadSpec, build_index,
                             draw_keys, get_preset, run_systems,
                             run_workload, scramble, write_json, zipf_ranks)
from repro.workloads.cli import main as cli_main

CFG = TreeConfig(n_ms=2, nodes_per_ms=2048, fanout=16, n_locks_per_ms=1024,
                 max_height=7, n_cs=4)
TINY = dict(load_records=2_000, ops=256, batch=128)


def _run(preset, system="sherman", **overrides):
    spec = get_preset(preset, **{**TINY, **overrides})
    idx = build_index(SYSTEMS[system], CFG, records=spec.load_records)
    return run_workload(idx, spec, system=system), idx


# -- the six standard YCSB presets, end to end ----------------------------

@pytest.mark.parametrize("preset", ["ycsb-a", "ycsb-b", "ycsb-c", "ycsb-d",
                                    "ycsb-e", "ycsb-f"])
def test_ycsb_preset_end_to_end(preset):
    r, idx = _run(preset)
    spec = PRESETS[preset]
    assert r.workload == preset and r.system == "sherman"
    assert r.n_ops == TINY["ops"] == sum(r.op_counts.values())
    assert r.mops > 0 and r.p50_us > 0 and r.p99_us >= r.p90_us >= r.p50_us
    # realized mix tracks the spec fractions (up to per-batch rounding)
    n_batches = TINY["ops"] // TINY["batch"]
    for kind, frac in spec.fractions().items():
        got = r.op_counts.get(kind, 0)
        assert abs(got - frac * r.n_ops) <= 2 * n_batches, (kind, got)
        if frac == 0:
            assert got == 0
    # the run is priced: netsim advanced and counted index-level ops
    assert r.counters["sim_time_s"] > 0
    assert r.counters["read_ops"] + r.counters["write_ops"] >= r.n_ops
    # results are json-serializable as-is
    json.dumps(r.to_dict())


def test_reads_hit_loaded_records():
    """Load phase + distribution draw target the same rank space."""
    spec = get_preset("ycsb-c", **TINY)
    idx = build_index(SYSTEMS["sherman"], CFG, records=spec.load_records)
    rng = np.random.default_rng(3)
    keys = draw_keys(rng, 512, distribution="zipfian", theta=0.99,
                     nspace=spec.load_records, keyspace=1 << 20)
    _, found = idx.lookup(keys.astype(np.int32))
    assert found.all()


def test_insert_grows_live_records_and_latest_reads_them():
    r, idx = _run("ycsb-d")
    n_ins = r.op_counts["insert"]
    assert n_ins > 0
    # the sequentially inserted ranks are live in the index
    new = scramble(np.arange(TINY["load_records"],
                             TINY["load_records"] + n_ins), 1 << 20)
    _, found = idx.lookup(new.astype(np.int32))
    assert found.all()


def test_delete_and_rmw_spec():
    spec = WorkloadSpec(name="churn", read=0.25, rmw=0.25, delete=0.25,
                        insert=0.25, **TINY)
    r, idx = _run("ycsb-a")  # warm index, then reuse it for the custom spec
    r2 = run_workload(idx, spec, system="sherman", seed=7)
    assert r2.n_ops == TINY["ops"]
    assert r2.op_counts["delete"] > 0 and r2.op_counts["rmw"] > 0
    # deltas: the second run's counters don't include the first run's
    assert r2.counters["read_ops"] <= r.counters["read_ops"] + \
        r2.n_ops * 2


def test_spec_validation():
    with pytest.raises(ValueError):
        WorkloadSpec(name="bad", read=0.5)            # fractions != 1
    with pytest.raises(ValueError):
        WorkloadSpec(name="bad", read=1.0, distribution="gaussian")
    with pytest.raises(KeyError):
        get_preset("ycsb-z")


def test_zipf_ranks_skew_and_uniform():
    rng = np.random.default_rng(0)
    ranks = zipf_ranks(rng, 20_000, 1 << 20, 0.99)
    # rank 0 is the hot key: ~6-7% of draws at theta=.99 over 2^20
    assert 0.04 < (ranks == 0).mean() < 0.12
    uni = zipf_ranks(rng, 20_000, 1 << 20, 0.0)
    assert (uni == 0).mean() < 0.01


def test_sherman_beats_fg_on_skewed_updates_via_engine():
    spec = get_preset("write-only", **TINY)
    res = {r.system: r for r in run_systems(spec, ("sherman", "fg+"), CFG)}
    assert res["sherman"].mops > 2 * res["fg+"].mops
    assert res["sherman"].p99_us < res["fg+"].p99_us


# -- CLI + JSON emission ---------------------------------------------------

def test_cli_writes_bench_json(tmp_path):
    out = tmp_path / "BENCH_cli.json"
    path = cli_main(["--preset", "ycsb-a", "--quick", "--records", "2000",
                     "--ops", "256", "--batch", "128",
                     "--json", str(out)])
    assert path == str(out) and out.exists()
    data = json.loads(out.read_text())
    assert data["spec"]["name"] == "ycsb-a"
    assert data["spec"]["ops"] == 256          # explicit flag beats --quick
    systems = {r["system"] for r in data["results"]}
    assert systems == {"sherman", "fg+"}
    for r in data["results"]:
        assert r["mops"] > 0 and r["p50_us"] > 0 and r["p99_us"] > 0


def test_cli_list_runs():
    assert cli_main(["--list"]) == ""


def test_write_json_roundtrip(tmp_path):
    r, _ = _run("ycsb-c")
    p = tmp_path / "BENCH_x.json"
    write_json(str(p), get_preset("ycsb-c", **TINY), [r],
               extra={"note": "roundtrip"})
    data = json.loads(p.read_text())
    assert data["note"] == "roundtrip"
    assert data["results"][0]["workload"] == "ycsb-c"


# -- the legacy benchmark surface stays alive ------------------------------

def test_benchmarks_common_shim():
    from benchmarks.common import build_index as bi
    from benchmarks.common import run_mix
    idx = bi(SYSTEMS["sherman"], CFG, bulk=2_000)
    r = run_mix(idx, read_frac=0.5, skew=0.99, n_ops=256, batch=128)
    assert r.mops > 0 and r.p99_us >= r.p50_us
